//! Tensor-level intermediate representation.
//!
//! The IR is a flat, append-only DAG of [`Node`]s held in a [`Graph`].
//! Every node carries an [`Op`] (operation kind plus static attributes),
//! its input node ids, and an inferred [`TensorType`] (shape + dtype +
//! optional packed layout + optional SBP distribution attribute).
//!
//! The same IR is used by every compiler phase: the importer / model
//! builders produce it, the e-graph rounds-trips it, Auto Distribution
//! annotates it with SBP attributes and boxing nodes, and codegen lowers
//! it to an [`crate::codegen::ExecPlan`].

mod dtype;
mod graph;
mod infer;
mod op;
mod shape;

pub use dtype::DType;
pub use graph::{Graph, Node, NodeId};
pub use infer::{infer_type, InferError};
pub use op::{BinaryKind, Op, ReduceKind, UnaryKind};
pub use shape::{Shape, TensorType};
