//! Shape / type inference for every [`Op`].

use super::{Op, Shape, TensorType};

/// Type-inference failure, carrying a human-readable explanation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InferError(pub String);

impl std::fmt::Display for InferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "type inference: {}", self.0)
    }
}

impl std::error::Error for InferError {}

fn err<T>(msg: impl Into<String>) -> Result<T, InferError> {
    Err(InferError(msg.into()))
}

/// Numpy-style broadcast of two shapes.
pub fn broadcast(a: &Shape, b: &Shape) -> Result<Shape, InferError> {
    let rank = a.rank().max(b.rank());
    let mut out = Vec::with_capacity(rank);
    for i in 0..rank {
        let da = if i + a.rank() >= rank { a.0[i + a.rank() - rank] } else { 1 };
        let db = if i + b.rank() >= rank { b.0[i + b.rank() - rank] } else { 1 };
        if da != db && da != 1 && db != 1 {
            return err(format!("cannot broadcast {a} with {b}"));
        }
        out.push(da.max(db));
    }
    Ok(Shape(out))
}

/// Infer the output type of `op` applied to `ins`.
pub fn infer_type(op: &Op, ins: &[&TensorType]) -> Result<TensorType, InferError> {
    if let Some(ar) = op.arity() {
        if ins.len() != ar {
            return err(format!("{} expects {ar} inputs, got {}", op.mnemonic(), ins.len()));
        }
    }
    match op {
        Op::Input(_) | Op::Const(_) => err("leaf nodes carry their own type"),
        Op::Scalar(_) => Ok(TensorType::of(&[], super::DType::F32)),

        Op::MatMul => {
            let (a, b) = (ins[0], ins[1]);
            if a.shape.rank() < 2 || b.shape.rank() < 2 {
                return err("matmul inputs must be rank >= 2");
            }
            if a.is_packed() != b.is_packed() {
                return err("matmul inputs must agree on packedness");
            }
            let (ar, br) = (a.shape.rank(), b.shape.rank());
            let (m, ka) = (a.shape.0[ar - 2], a.shape.0[ar - 1]);
            let (kb, n) = (b.shape.0[br - 2], b.shape.0[br - 1]);
            if ka != kb {
                return err(format!("matmul k mismatch: {} vs {}", ka, kb));
            }
            // Batch dims broadcast.
            let abatch = Shape(a.shape.0[..ar - 2].to_vec());
            let bbatch = Shape(b.shape.0[..br - 2].to_vec());
            let mut dims = broadcast(&abatch, &bbatch)?.0;
            dims.push(m);
            dims.push(n);
            let mut ty = TensorType::new(Shape(dims), a.dtype);
            if a.is_packed() {
                // Packed matmul keeps the block structure: [M',K']<lm,lk> x
                // [K',N']<lk,ln> -> [M',N']<lm,ln>.
                if a.lanes.len() != 2 || b.lanes.len() != 2 || a.lanes[1] != b.lanes[0] {
                    return err("packed matmul lane mismatch");
                }
                ty.lanes = vec![a.lanes[0], b.lanes[1]];
                ty.pack_axes = vec![ty.shape.rank() - 2, ty.shape.rank() - 1];
            }
            Ok(ty)
        }

        Op::Unary(_) => Ok(ins[0].clone()),

        Op::Binary(_) => {
            let (a, b) = (ins[0], ins[1]);
            if a.dtype != b.dtype && !(a.shape.rank() == 0 || b.shape.rank() == 0) {
                return err(format!("binary dtype mismatch: {} vs {}", a.dtype, b.dtype));
            }
            if a.is_packed() != b.is_packed()
                && a.shape.rank() != 0
                && b.shape.rank() != 0
            {
                return err("binary packedness mismatch");
            }
            let shape = broadcast(&a.shape, &b.shape)?;
            let wide = if a.shape.rank() >= b.shape.rank() { a } else { b };
            let mut ty = TensorType::new(shape, wide.dtype);
            ty.lanes = wide.lanes.clone();
            ty.pack_axes = wide.pack_axes.clone();
            Ok(ty)
        }

        Op::Reduce { axis, keep_dim, .. } => {
            let x = ins[0];
            if *axis >= x.shape.rank() {
                return err("reduce axis out of range");
            }
            let mut dims = x.shape.0.clone();
            if *keep_dim {
                dims[*axis] = 1;
            } else {
                dims.remove(*axis);
            }
            Ok(TensorType::new(Shape(dims), x.dtype))
        }

        Op::Softmax { axis } => {
            if *axis >= ins[0].shape.rank() {
                return err("softmax axis out of range");
            }
            Ok(ins[0].clone())
        }

        Op::RmsNorm { .. } => {
            let (x, w) = (ins[0], ins[1]);
            let last = *x.shape.0.last().ok_or_else(|| InferError("rmsnorm on scalar".into()))?;
            if w.shape.dims() != [last] {
                return err(format!("rmsnorm weight must be [{last}], got {}", w.shape));
            }
            Ok(x.clone())
        }

        Op::Rope { .. } => Ok(ins[0].clone()),

        Op::Transpose { perm } => {
            let x = ins[0];
            if perm.len() != x.shape.rank() {
                return err("transpose perm rank mismatch");
            }
            let mut seen = vec![false; perm.len()];
            for &p in perm {
                if p >= perm.len() || std::mem::replace(&mut seen[p], true) {
                    return err("transpose perm is not a permutation");
                }
            }
            let mut ty = x.clone();
            ty.shape = x.shape.permute(perm);
            Ok(ty)
        }

        Op::Reshape { shape } => {
            let x = ins[0];
            if shape.numel() != x.shape.numel() {
                return err(format!("reshape {} -> {} changes element count", x.shape, shape));
            }
            let mut ty = x.clone();
            ty.shape = shape.clone();
            Ok(ty)
        }

        Op::Slice { axis, start, stop } => {
            let x = ins[0];
            if *axis >= x.shape.rank() || start >= stop || *stop > x.shape.0[*axis] {
                return err("slice out of range");
            }
            let mut ty = x.clone();
            ty.shape.0[*axis] = stop - start;
            Ok(ty)
        }

        Op::Concat { axis } => {
            if ins.is_empty() {
                return err("concat needs at least one input");
            }
            let first = ins[0];
            if *axis >= first.shape.rank() {
                return err("concat axis out of range");
            }
            let mut dims = first.shape.0.clone();
            for t in &ins[1..] {
                if t.shape.rank() != first.shape.rank() || t.dtype != first.dtype {
                    return err("concat inputs must have same rank/dtype");
                }
                for (i, (&a, &b)) in t.shape.0.iter().zip(&first.shape.0).enumerate() {
                    if i != *axis && a != b {
                        return err("concat non-axis dims must match");
                    }
                }
                dims[*axis] += t.shape.0[*axis];
            }
            dims[*axis] = dims[*axis] - first.shape.0[*axis] + first.shape.0[*axis];
            Ok(TensorType::new(Shape(dims), first.dtype))
        }

        Op::Gather => {
            let (table, ids) = (ins[0], ins[1]);
            if table.shape.rank() != 2 || ids.shape.rank() != 1 {
                return err("gather expects (table[v,h], ids[n])");
            }
            Ok(TensorType::of(&[ids.shape.0[0], table.shape.0[1]], table.dtype))
        }

        Op::Pack { lanes, axes } => {
            let x = ins[0];
            if x.is_packed() {
                return err("pack of already-packed tensor");
            }
            if lanes.len() != axes.len() || lanes.is_empty() {
                return err("pack lanes/axes mismatch");
            }
            let mut ty = x.clone();
            for (&l, &ax) in lanes.iter().zip(axes) {
                if ax >= ty.shape.rank() {
                    return err("pack axis out of range");
                }
                if ty.shape.0[ax] % l != 0 {
                    return err(format!(
                        "pack lane {l} does not divide dim {} of {}",
                        ty.shape.0[ax], ty.shape
                    ));
                }
                ty.shape.0[ax] /= l;
            }
            ty.lanes = lanes.clone();
            ty.pack_axes = axes.clone();
            Ok(ty)
        }

        Op::Unpack { axes } => {
            let x = ins[0];
            if !x.is_packed() {
                return err("unpack of flat tensor");
            }
            if *axes != x.pack_axes {
                return err("unpack axes must match the pack axes");
            }
            let mut ty = x.clone();
            for (&l, &ax) in x.lanes.iter().zip(&x.pack_axes) {
                ty.shape.0[ax] *= l;
            }
            ty.lanes.clear();
            ty.pack_axes.clear();
            Ok(ty)
        }

        Op::Boxing { to } => Ok(ins[0].with_sbp(to.clone())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{BinaryKind, DType};

    fn t(dims: &[usize]) -> TensorType {
        TensorType::of(dims, DType::F32)
    }

    #[test]
    fn broadcast_shapes() {
        assert_eq!(broadcast(&Shape::of(&[4, 1]), &Shape::of(&[3])).unwrap().dims(), &[4, 3]);
        assert!(broadcast(&Shape::of(&[2]), &Shape::of(&[3])).is_err());
    }

    #[test]
    fn matmul_batched() {
        let a = t(&[8, 2, 3]);
        let b = t(&[3, 4]);
        let out = infer_type(&Op::MatMul, &[&a, &b]).unwrap();
        assert_eq!(out.shape.dims(), &[8, 2, 4]);
        assert!(infer_type(&Op::MatMul, &[&t(&[2, 3]), &t(&[4, 5])]).is_err());
    }

    #[test]
    fn packed_matmul_lanes() {
        let mut a = t(&[4, 2]);
        a.lanes = vec![16, 32];
        a.pack_axes = vec![0, 1];
        let mut b = t(&[2, 8]);
        b.lanes = vec![32, 16];
        b.pack_axes = vec![0, 1];
        let out = infer_type(&Op::MatMul, &[&a, &b]).unwrap();
        assert_eq!(out.shape.dims(), &[4, 8]);
        assert_eq!(out.lanes, vec![16, 16]);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let x = t(&[64, 128]);
        let packed =
            infer_type(&Op::Pack { lanes: vec![16, 16], axes: vec![0, 1] }, &[&x]).unwrap();
        assert_eq!(packed.shape.dims(), &[4, 8]);
        assert_eq!(packed.lanes, vec![16, 16]);
        let back = infer_type(&Op::Unpack { axes: vec![0, 1] }, &[&packed]).unwrap();
        assert_eq!(back, x);
    }

    #[test]
    fn pack_requires_divisibility() {
        let x = t(&[60, 128]);
        assert!(infer_type(&Op::Pack { lanes: vec![16, 16], axes: vec![0, 1] }, &[&x]).is_err());
    }

    #[test]
    fn transpose_validation() {
        let x = t(&[2, 3, 4]);
        let ty = infer_type(&Op::Transpose { perm: vec![2, 0, 1] }, &[&x]).unwrap();
        assert_eq!(ty.shape.dims(), &[4, 2, 3]);
        assert!(infer_type(&Op::Transpose { perm: vec![0, 0, 1] }, &[&x]).is_err());
    }

    #[test]
    fn binary_broadcast_and_scalar() {
        let a = t(&[4, 4]);
        let s = t(&[]);
        let out = infer_type(&Op::Binary(BinaryKind::Add), &[&a, &s]).unwrap();
        assert_eq!(out.shape.dims(), &[4, 4]);
    }

    #[test]
    fn concat_infers_sum() {
        let a = t(&[2, 3]);
        let b = t(&[2, 5]);
        let out = infer_type(&Op::Concat { axis: 1 }, &[&a, &b]).unwrap();
        assert_eq!(out.shape.dims(), &[2, 8]);
        assert!(infer_type(&Op::Concat { axis: 0 }, &[&a, &b]).is_err());
    }

    #[test]
    fn reduce_keepdim() {
        let x = t(&[2, 3, 4]);
        let op = Op::Reduce { kind: crate::ir::ReduceKind::Sum, axis: 1, keep_dim: true };
        assert_eq!(infer_type(&op, &[&x]).unwrap().shape.dims(), &[2, 1, 4]);
        let op = Op::Reduce { kind: crate::ir::ReduceKind::Sum, axis: 1, keep_dim: false };
        assert_eq!(infer_type(&op, &[&x]).unwrap().shape.dims(), &[2, 4]);
    }
}
