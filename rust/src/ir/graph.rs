//! The IR graph: a flat DAG of nodes with a builder API.

use std::collections::HashMap;


use super::{infer_type, DType, Op, Shape, TensorType};

/// Index of a node inside a [`Graph`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// One IR node.
#[derive(Debug, Clone)]
pub struct Node {
    pub op: Op,
    pub inputs: Vec<NodeId>,
    pub ty: TensorType,
}

/// A computation graph. Nodes are append-only and always stored in a
/// valid topological order (inputs precede users).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    pub outputs: Vec<NodeId>,
    /// De-duplication memo: identical (op, inputs) pairs share a node.
    memo: HashMap<(Op, Vec<NodeId>), NodeId>,
}

impl Graph {
    pub fn new() -> Self {
        Graph::default()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.index()]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a node, de-duplicating structurally identical ones (hash-consing).
    /// Panics if type inference fails — graph construction bugs are
    /// programmer errors, not runtime conditions.
    pub fn add(&mut self, op: Op, inputs: &[NodeId]) -> NodeId {
        self.try_add(op, inputs).expect("type inference failed")
    }

    /// Fallible [`Graph::add`].
    pub fn try_add(&mut self, op: Op, inputs: &[NodeId]) -> Result<NodeId, super::InferError> {
        let key = (op.clone(), inputs.to_vec());
        if let Some(&id) = self.memo.get(&key) {
            return Ok(id);
        }
        let in_tys: Vec<&TensorType> = inputs.iter().map(|&i| &self.node(i).ty).collect();
        let ty = infer_type(&op, &in_tys)?;
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node { op, inputs: inputs.to_vec(), ty });
        self.memo.insert(key, id);
        Ok(id)
    }

    /// Mark a node as a graph output.
    pub fn mark_output(&mut self, id: NodeId) {
        if !self.outputs.contains(&id) {
            self.outputs.push(id);
        }
    }

    // ---- convenience builders -------------------------------------------

    pub fn input(&mut self, name: &str, dims: &[usize], dtype: DType) -> NodeId {
        let mut n = Node {
            op: Op::Input(name.to_string()),
            inputs: vec![],
            ty: TensorType::of(dims, dtype),
        };
        // Inputs with the same name must be distinct nodes only if their
        // types differ; hash-consing handles the common case.
        let key = (n.op.clone(), vec![]);
        if let Some(&id) = self.memo.get(&key) {
            return id;
        }
        let id = NodeId(self.nodes.len() as u32);
        n.ty = TensorType::of(dims, dtype);
        self.nodes.push(n);
        self.memo.insert(key, id);
        id
    }

    pub fn constant(&mut self, name: &str, dims: &[usize], dtype: DType) -> NodeId {
        let key = (Op::Const(name.to_string()), vec![]);
        if let Some(&id) = self.memo.get(&key) {
            return id;
        }
        let id = NodeId(self.nodes.len() as u32);
        self.nodes.push(Node {
            op: Op::Const(name.to_string()),
            inputs: vec![],
            ty: TensorType::of(dims, dtype),
        });
        self.memo.insert(key, id);
        id
    }

    pub fn matmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        self.add(Op::MatMul, &[a, b])
    }

    pub fn unary(&mut self, kind: super::UnaryKind, x: NodeId) -> NodeId {
        self.add(Op::Unary(kind), &[x])
    }

    pub fn binary(&mut self, kind: super::BinaryKind, a: NodeId, b: NodeId) -> NodeId {
        self.add(Op::Binary(kind), &[a, b])
    }

    pub fn transpose(&mut self, x: NodeId, perm: &[usize]) -> NodeId {
        self.add(Op::Transpose { perm: perm.to_vec() }, &[x])
    }

    pub fn reshape(&mut self, x: NodeId, dims: &[usize]) -> NodeId {
        self.add(Op::Reshape { shape: Shape::of(dims) }, &[x])
    }

    pub fn softmax(&mut self, x: NodeId, axis: usize) -> NodeId {
        self.add(Op::Softmax { axis }, &[x])
    }

    /// Users of each node (computed on demand).
    pub fn users(&self) -> Vec<Vec<NodeId>> {
        let mut users = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for &inp in &n.inputs {
                users[inp.index()].push(NodeId(i as u32));
            }
        }
        users
    }

    /// Nodes reachable from the outputs, in topological order.
    pub fn live_nodes(&self) -> Vec<NodeId> {
        let mut live = vec![false; self.nodes.len()];
        let mut stack: Vec<NodeId> = self.outputs.clone();
        while let Some(id) = stack.pop() {
            if std::mem::replace(&mut live[id.index()], true) {
                continue;
            }
            stack.extend(self.node(id).inputs.iter().copied());
        }
        (0..self.nodes.len() as u32).map(NodeId).filter(|id| live[id.index()]).collect()
    }

    /// Pretty-print the graph, one node per line.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for (i, n) in self.nodes.iter().enumerate() {
            let args: Vec<String> = n.inputs.iter().map(|x| format!("%{}", x.0)).collect();
            let out = if self.outputs.contains(&NodeId(i as u32)) { " (output)" } else { "" };
            s.push_str(&format!(
                "%{i}: {} = {}({}){out}\n",
                n.ty,
                n.op.mnemonic(),
                args.join(", ")
            ));
        }
        s
    }

    /// Total FLOPs of all live nodes (see [`crate::cost::op_flops`]).
    pub fn total_flops(&self) -> u64 {
        self.live_nodes()
            .iter()
            .map(|&id| {
                let n = self.node(id);
                let in_tys: Vec<&TensorType> =
                    n.inputs.iter().map(|&i| &self.node(i).ty).collect();
                crate::cost::op_flops(&n.op, &in_tys, &n.ty)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{BinaryKind, UnaryKind};

    #[test]
    fn build_and_dedup() {
        let mut g = Graph::new();
        let a = g.input("a", &[2, 3], DType::F32);
        let b = g.input("b", &[3, 4], DType::F32);
        let m1 = g.matmul(a, b);
        let m2 = g.matmul(a, b);
        assert_eq!(m1, m2, "hash-consing must dedup identical nodes");
        assert_eq!(g.node(m1).ty.shape.dims(), &[2, 4]);
    }

    #[test]
    fn live_nodes_skips_dead() {
        let mut g = Graph::new();
        let a = g.input("a", &[4], DType::F32);
        let _dead = g.unary(UnaryKind::Neg, a);
        let live = g.unary(UnaryKind::Exp, a);
        g.mark_output(live);
        let ids = g.live_nodes();
        assert_eq!(ids.len(), 2); // input + exp
    }

    #[test]
    fn users() {
        let mut g = Graph::new();
        let a = g.input("a", &[4], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        let s = g.binary(BinaryKind::Add, e, a);
        let users = g.users();
        assert_eq!(users[a.index()].len(), 2);
        assert_eq!(users[e.index()], vec![s]);
    }

    #[test]
    fn dump_contains_ops() {
        let mut g = Graph::new();
        let a = g.input("a", &[2, 2], DType::F32);
        let t = g.transpose(a, &[1, 0]);
        g.mark_output(t);
        let d = g.dump();
        assert!(d.contains("transpose"));
        assert!(d.contains("(output)"));
    }
}
