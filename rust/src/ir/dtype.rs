//! Element datatypes.


/// Element datatype of a tensor.
///
/// `F16`/`BF16` are carried symbolically through the compiler and the
/// performance simulator (they halve memory traffic, the dominant term of
/// LLM decode); the real NTT execution backend computes in `F32` and the
/// PJRT backend executes whatever the artifact was lowered with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    BF16,
    I32,
    I8,
    Bool,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 | DType::BF16 => 2,
            DType::I8 | DType::Bool => 1,
        }
    }

    /// True for floating-point types.
    pub fn is_float(self) -> bool {
        matches!(self, DType::F32 | DType::F16 | DType::BF16)
    }

    /// Short lowercase name used in artifact manifests and NTT C++
    /// emission (`float`, `half`, ...).
    pub fn cpp_name(self) -> &'static str {
        match self {
            DType::F32 => "float",
            DType::F16 => "half",
            DType::BF16 => "bfloat16",
            DType::I32 => "int32_t",
            DType::I8 => "int8_t",
            DType::Bool => "bool",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            DType::F32 => "f32",
            DType::F16 => "f16",
            DType::BF16 => "bf16",
            DType::I32 => "i32",
            DType::I8 => "i8",
            DType::Bool => "bool",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::F16.size_bytes(), 2);
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert_eq!(DType::I8.size_bytes(), 1);
    }

    #[test]
    fn float_predicate() {
        assert!(DType::F32.is_float());
        assert!(DType::BF16.is_float());
        assert!(!DType::I32.is_float());
    }

    #[test]
    fn display_roundtrip_names() {
        assert_eq!(DType::F32.to_string(), "f32");
        assert_eq!(DType::F16.to_string(), "f16");
        assert_eq!(DType::F32.cpp_name(), "float");
    }
}
