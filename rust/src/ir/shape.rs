//! Shapes, packed layouts and tensor types.


use super::DType;
use crate::dist::NdSbp;

/// A dense row-major tensor shape.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn scalar() -> Self {
        Shape(vec![])
    }

    pub fn of(dims: &[usize]) -> Self {
        Shape(dims.to_vec())
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Total number of elements.
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Row-major strides, in elements.
    pub fn strides(&self) -> Vec<usize> {
        let mut strides = vec![1usize; self.rank()];
        for i in (0..self.rank().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.0[i + 1];
        }
        strides
    }

    /// Apply a permutation (output dim `i` takes input dim `perm[i]`).
    pub fn permute(&self, perm: &[usize]) -> Shape {
        debug_assert_eq!(perm.len(), self.rank());
        Shape(perm.iter().map(|&p| self.0[p]).collect())
    }

    /// True if `perm` is the identity permutation.
    pub fn is_identity_perm(perm: &[usize]) -> bool {
        perm.iter().enumerate().all(|(i, &p)| i == p)
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// The full static type of an IR value.
///
/// `lanes`/`pack_axes` describe the packed (blocked) layout produced by
/// `Pack` nodes: `lanes = [16,16], pack_axes = [0,1]` means the logical
/// tensor was reorganised so that 16×16 blocks of (axis0, axis1) are
/// contiguous — the blocked format the paper feeds to tensor units
/// (§3.1.2). An empty `lanes` is the flat (unpacked) layout.
///
/// `sbp` is the distribution attribute attached by Auto Distribution
/// (§3.1.3); `None` means host-resident / undistributed.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorType {
    pub shape: Shape,
    pub dtype: DType,
    pub lanes: Vec<usize>,
    pub pack_axes: Vec<usize>,
    pub sbp: Option<NdSbp>,
}

impl TensorType {
    pub fn new(shape: Shape, dtype: DType) -> Self {
        TensorType { shape, dtype, lanes: vec![], pack_axes: vec![], sbp: None }
    }

    pub fn of(dims: &[usize], dtype: DType) -> Self {
        Self::new(Shape::of(dims), dtype)
    }

    pub fn is_packed(&self) -> bool {
        !self.lanes.is_empty()
    }

    /// Number of *logical* elements (pack blocks count as lanes elements).
    pub fn numel(&self) -> usize {
        self.shape.numel() * self.lanes.iter().product::<usize>()
    }

    /// Size in bytes of the full (local, undistributed) tensor.
    pub fn size_bytes(&self) -> usize {
        self.numel() * self.dtype.size_bytes()
    }

    /// Size in bytes of one device's shard under the current SBP
    /// attribute on `placement` (product of mesh dims that split it).
    pub fn local_size_bytes(&self, placement_dims: &[usize]) -> usize {
        let mut size = self.size_bytes();
        if let Some(sbp) = &self.sbp {
            for (mesh_axis, s) in sbp.0.iter().enumerate() {
                if let crate::dist::Sbp::Split(_) = s {
                    let p = placement_dims.get(mesh_axis).copied().unwrap_or(1);
                    size = size.div_ceil(p);
                }
            }
        }
        size
    }

    /// Same type with a different SBP attribute.
    pub fn with_sbp(&self, sbp: Option<NdSbp>) -> Self {
        let mut t = self.clone();
        t.sbp = sbp;
        t
    }
}

impl std::fmt::Display for TensorType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}{}", self.dtype, self.shape)?;
        if self.is_packed() {
            write!(f, "<")?;
            for (i, l) in self.lanes.iter().enumerate() {
                if i > 0 {
                    write!(f, ",")?;
                }
                write!(f, "{l}")?;
            }
            write!(f, ">")?;
        }
        if let Some(sbp) = &self.sbp {
            write!(f, "@{sbp}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_strides() {
        let s = Shape::of(&[2, 3, 4]);
        assert_eq!(s.numel(), 24);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(Shape::scalar().numel(), 1);
        assert_eq!(Shape::scalar().strides(), Vec::<usize>::new());
    }

    #[test]
    fn permute() {
        let s = Shape::of(&[2, 3, 4]);
        assert_eq!(s.permute(&[2, 0, 1]).dims(), &[4, 2, 3]);
        assert!(Shape::is_identity_perm(&[0, 1, 2]));
        assert!(!Shape::is_identity_perm(&[1, 0]));
    }

    #[test]
    fn packed_type_sizes() {
        // [8, 8]<16,16> == logical [128, 128] f32 = 64 KiB
        let mut t = TensorType::of(&[8, 8], DType::F32);
        t.lanes = vec![16, 16];
        t.pack_axes = vec![0, 1];
        assert_eq!(t.numel(), 128 * 128);
        assert_eq!(t.size_bytes(), 128 * 128 * 4);
        assert_eq!(t.to_string(), "f32[8,8]<16,16>");
    }

    #[test]
    fn local_size_under_split() {
        use crate::dist::{NdSbp, Sbp};
        let t = TensorType::of(&[1024, 1024], DType::F16)
            .with_sbp(Some(NdSbp(vec![Sbp::Split(0)])));
        assert_eq!(t.size_bytes(), 1024 * 1024 * 2);
        assert_eq!(t.local_size_bytes(&[4]), 1024 * 1024 * 2 / 4);
        // Broadcast does not shrink the local shard.
        let tb = t.with_sbp(Some(NdSbp(vec![Sbp::Broadcast])));
        assert_eq!(tb.local_size_bytes(&[4]), 1024 * 1024 * 2);
    }
}
