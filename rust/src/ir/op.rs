//! Operation kinds and their static attributes.


use super::Shape;
use crate::dist::NdSbp;

/// Element-wise unary operator kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnaryKind {
    Exp,
    Neg,
    Sqrt,
    Rsqrt,
    Silu,
    Abs,
    Log,
}

/// Element-wise binary operator kinds (broadcasting, numpy-style).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BinaryKind {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
}

/// Reduction kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    Sum,
    Max,
    Mean,
}

/// An IR operation: the kind plus all static attributes.
///
/// Children are stored in the owning node / e-node, not here, so `Op`
/// itself is hashable and serves as the e-node label.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum Op {
    /// Graph input (activation). Attribute: stable name.
    Input(String),
    /// Weight / constant tensor. Attribute: stable name. Constants are
    /// pre-split per their SBP attribute at codegen time (§3.3.1).
    Const(String),
    /// Scalar float constant materialized in the graph.
    Scalar(u32 /* f32 bits, kept as bits for Eq/Hash */),

    /// Dense matrix multiply over the last two dims (leading dims batch).
    MatMul,
    /// Element-wise unary.
    Unary(UnaryKind),
    /// Element-wise binary with numpy broadcasting.
    Binary(BinaryKind),
    /// Reduction over one axis. `keep_dim` keeps the reduced axis as 1.
    Reduce { kind: ReduceKind, axis: usize, keep_dim: bool },
    /// Softmax over `axis` (kept fused — it is an NTT μkernel).
    Softmax { axis: usize },
    /// RMS normalization over the last axis with weight input.
    RmsNorm { eps_bits: u32 },
    /// Rotary position embedding over the last axis; attribute: rotary base.
    Rope { theta_bits: u32 },

    /// Transpose by `perm` (output dim i reads input dim perm[i]).
    Transpose { perm: Vec<usize> },
    /// Reshape to `shape` (view — zero-copy after bufferization).
    Reshape { shape: Shape },
    /// Slice `[start, stop)` on `axis` (view).
    Slice { axis: usize, start: usize, stop: usize },
    /// Concatenate along `axis`.
    Concat { axis: usize },
    /// Embedding row gather: (table[v, h], ids[n]) -> [n, h].
    Gather,

    /// Layout pack (§3.1.2): fold `lanes[i]` elements of `axes[i]` into a
    /// trailing contiguous block dimension, producing a blocked layout.
    Pack { lanes: Vec<usize>, axes: Vec<usize> },
    /// Inverse of `Pack`.
    Unpack { axes: Vec<usize> },

    /// Boxing (§3.1.3): the unified communication primitive. Converts a
    /// tensor's distribution attribute to `to` (splitting, broadcasting,
    /// all-reducing, resharding as needed). `to == None` gathers the full
    /// tensor back to the host (Unshard).
    Boxing { to: Option<NdSbp> },
}

impl Op {
    /// True for ops with *view semantics*: their output aliases the input
    /// buffer (zero-copy after alias analysis, §3.3.1).
    pub fn is_view(&self) -> bool {
        matches!(self, Op::Reshape { .. } | Op::Slice { .. })
    }

    /// True for element-wise ops (packable with any lane structure).
    pub fn is_elementwise(&self) -> bool {
        matches!(self, Op::Unary(_) | Op::Binary(_))
    }

    /// True for leaf (no-input) ops.
    pub fn is_leaf(&self) -> bool {
        matches!(self, Op::Input(_) | Op::Const(_) | Op::Scalar(_))
    }

    /// Number of inputs this op expects (`None` = variadic).
    pub fn arity(&self) -> Option<usize> {
        Some(match self {
            Op::Input(_) | Op::Const(_) | Op::Scalar(_) => 0,
            Op::MatMul | Op::Binary(_) | Op::Gather => 2,
            Op::RmsNorm { .. } => 2,
            Op::Unary(_)
            | Op::Reduce { .. }
            | Op::Softmax { .. }
            | Op::Rope { .. }
            | Op::Transpose { .. }
            | Op::Reshape { .. }
            | Op::Slice { .. }
            | Op::Pack { .. }
            | Op::Unpack { .. }
            | Op::Boxing { .. } => 1,
            Op::Concat { .. } => return None,
        })
    }

    /// Short mnemonic used in dumps, cost tables and emitted code.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            Op::Input(_) => "input",
            Op::Const(_) => "const",
            Op::Scalar(_) => "scalar",
            Op::MatMul => "matmul",
            Op::Unary(UnaryKind::Exp) => "exp",
            Op::Unary(UnaryKind::Neg) => "neg",
            Op::Unary(UnaryKind::Sqrt) => "sqrt",
            Op::Unary(UnaryKind::Rsqrt) => "rsqrt",
            Op::Unary(UnaryKind::Silu) => "silu",
            Op::Unary(UnaryKind::Abs) => "abs",
            Op::Unary(UnaryKind::Log) => "log",
            Op::Binary(BinaryKind::Add) => "add",
            Op::Binary(BinaryKind::Sub) => "sub",
            Op::Binary(BinaryKind::Mul) => "mul",
            Op::Binary(BinaryKind::Div) => "div",
            Op::Binary(BinaryKind::Max) => "max",
            Op::Binary(BinaryKind::Min) => "min",
            Op::Reduce { .. } => "reduce",
            Op::Softmax { .. } => "softmax",
            Op::RmsNorm { .. } => "rmsnorm",
            Op::Rope { .. } => "rope",
            Op::Transpose { .. } => "transpose",
            Op::Reshape { .. } => "reshape",
            Op::Slice { .. } => "slice",
            Op::Concat { .. } => "concat",
            Op::Gather => "gather",
            Op::Pack { .. } => "pack",
            Op::Unpack { .. } => "unpack",
            Op::Boxing { .. } => "boxing",
        }
    }

    /// Helper: scalar constant from an f32.
    pub fn scalar(v: f32) -> Op {
        Op::Scalar(v.to_bits())
    }

    /// Value of a `Scalar` op.
    pub fn scalar_value(&self) -> Option<f32> {
        match self {
            Op::Scalar(bits) => Some(f32::from_bits(*bits)),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_semantics() {
        assert!(Op::Reshape { shape: Shape::of(&[2, 2]) }.is_view());
        assert!(Op::Slice { axis: 0, start: 0, stop: 1 }.is_view());
        assert!(!Op::MatMul.is_view());
    }

    #[test]
    fn arity() {
        assert_eq!(Op::MatMul.arity(), Some(2));
        assert_eq!(Op::Concat { axis: 0 }.arity(), None);
        assert_eq!(Op::Input("x".into()).arity(), Some(0));
    }

    #[test]
    fn scalar_bits_roundtrip() {
        let op = Op::scalar(2.5);
        assert_eq!(op.scalar_value(), Some(2.5));
        // Eq/Hash work through the bit pattern.
        assert_eq!(op, Op::scalar(2.5));
        assert_ne!(op, Op::scalar(2.0));
    }
}
