//! Qwen3-family model descriptions (§4's evaluation subjects).
//!
//! Three things live here:
//! * [`Qwen3Config`] — architecture hyper-parameters at the paper's true
//!   scales (0.6B / 1.7B) plus a `tiny` config for real end-to-end
//!   execution.
//! * [`decode_graph`] — one decode step as an IR [`Graph`] (the compiler
//!   input: RMSNorm → GQA attention with RoPE → SwiGLU MLP per layer).
//! * [`Qwen3Weights`] — deterministic random weights for the NTT
//!   execution backend.

use crate::ir::{BinaryKind, DType, Graph, NodeId, Op, UnaryKind};
use crate::ntt::{QuantMat, Tensor, WeightQuant};
use crate::util::Rng;

/// Qwen3 architecture hyper-parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Qwen3Config {
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    pub intermediate: usize,
    pub vocab: usize,
    pub dtype: DType,
    /// RoPE base.
    pub rope_theta: f32,
    pub rms_eps: f32,
    /// Storage format of the GEMM weight plane (projections + LM head):
    /// `F32` is the unquantized seed path; `Int8`/`Int4` store
    /// group-wise affine codes that the engines stream through fused
    /// dequant-GEMM kernels (embedding and norm vectors always stay in
    /// `dtype`). Threaded through engine build (`Qwen3Engine`,
    /// `BatchEngine`) and priced by [`Qwen3Config::weight_bytes`].
    pub weight_quant: WeightQuant,
}

impl Qwen3Config {
    /// Qwen3-0.6B (28 layers, hidden 1024, GQA 16/8, head_dim 128).
    pub fn qwen3_0_6b(dtype: DType) -> Self {
        Qwen3Config {
            name: format!("Qwen3-0.6B-{dtype}"),
            hidden: 1024,
            layers: 28,
            heads: 16,
            kv_heads: 8,
            head_dim: 128,
            intermediate: 3072,
            vocab: 151_936,
            dtype,
            rope_theta: 1.0e6,
            rms_eps: 1e-6,
            weight_quant: WeightQuant::F32,
        }
    }

    /// Qwen3-1.7B (28 layers, hidden 2048, GQA 16/8, head_dim 128).
    pub fn qwen3_1_7b(dtype: DType) -> Self {
        Qwen3Config {
            name: format!("Qwen3-1.7B-{dtype}"),
            hidden: 2048,
            layers: 28,
            heads: 16,
            kv_heads: 8,
            head_dim: 128,
            intermediate: 6144,
            vocab: 151_936,
            dtype,
            rope_theta: 1.0e6,
            rms_eps: 1e-6,
            weight_quant: WeightQuant::F32,
        }
    }

    /// A Qwen3-shaped ~15M-parameter config for real execution in tests,
    /// examples and the E2E serving driver.
    pub fn tiny() -> Self {
        Qwen3Config {
            name: "Qwen3-tiny-f32".into(),
            hidden: 256,
            layers: 4,
            heads: 4,
            kv_heads: 2,
            head_dim: 64,
            intermediate: 768,
            vocab: 4096,
            dtype: DType::F32,
            rope_theta: 1.0e4,
            rms_eps: 1e-6,
            weight_quant: WeightQuant::F32,
        }
    }

    /// Parameter count (embeddings + per-layer weights + head, untied).
    pub fn param_count(&self) -> u64 {
        let h = self.hidden as u64;
        let hd = (self.heads * self.head_dim) as u64;
        let kvd = (self.kv_heads * self.head_dim) as u64;
        let inter = self.intermediate as u64;
        let per_layer = h * hd      // Wq
            + h * kvd * 2           // Wk, Wv
            + hd * h                // Wo
            + h * inter * 2         // W_gate, W_up
            + inter * h             // W_down
            + h * 2                 // norms
            + self.head_dim as u64 * 2; // q/k norms (Qwen3 uses QK-norm)
        self.vocab as u64 * h       // embedding
            + per_layer * self.layers as u64
            + h                     // final norm
            + h * self.vocab as u64 // lm head
    }

    /// Builder: the same architecture with the GEMM weight plane stored
    /// as `quant` (see [`WeightQuant`]).
    pub fn with_weight_quant(mut self, quant: WeightQuant) -> Self {
        self.weight_quant = quant;
        self
    }

    /// `(k, n)` shapes of the quantizable GEMM matrices as the engines
    /// pack them: the 7 per-layer projections plus the LM head.
    /// Embedding and norm vectors are not GEMM operands and stay in
    /// `dtype`.
    fn matrix_shapes(&self) -> Vec<(usize, usize)> {
        let h = self.hidden;
        let qd = self.heads * self.head_dim;
        let kvd = self.kv_heads * self.head_dim;
        let inter = self.intermediate;
        let mut shapes = Vec::with_capacity(self.layers * 7 + 1);
        for _ in 0..self.layers {
            shapes.extend_from_slice(&[
                (h, qd),    // wq
                (h, kvd),   // wk
                (h, kvd),   // wv
                (qd, h),    // wo
                (h, inter), // w_gate
                (h, inter), // w_up
                (inter, h), // w_down
            ]);
        }
        shapes.push((h, self.vocab)); // lm_head
        shapes
    }

    /// Parameters of the quantizable GEMM weight plane (the matrices
    /// `weight_quant` applies to).
    pub fn matrix_param_count(&self) -> u64 {
        self.matrix_shapes().iter().map(|&(k, n)| (k * n) as u64).sum()
    }

    /// Bytes of the GEMM weight plane in the `weight_quant` format
    /// (payload + group scale/zero overhead, exact per-matrix group
    /// accounting — see [`WeightQuant::matrix_bytes`]).
    pub fn matrix_weight_bytes(&self) -> u64 {
        let nb = self.dtype.size_bytes();
        self.matrix_shapes()
            .iter()
            .map(|&(k, n)| self.weight_quant.matrix_bytes(k, n, nb))
            .sum()
    }

    /// Bytes of all weights as the engines store them (the *resident*
    /// footprint): the GEMM matrices in the `weight_quant` format,
    /// everything else (embedding, norms) in `dtype`. The pre-quant
    /// version priced every parameter at `dtype` width; once the weight
    /// plane is quantized that assumption is dead — it overstated the
    /// reservation `MachineSpec::kv_block_budget` callers subtract from
    /// machine memory. For the per-token weight *traffic* see
    /// [`Qwen3Config::decode_stream_bytes`].
    pub fn weight_bytes(&self) -> u64 {
        let rest = self.param_count() - self.matrix_param_count();
        self.matrix_weight_bytes() + rest * self.dtype.size_bytes() as u64
    }

    /// Bytes one decode step actually *streams*: the GEMM plane in the
    /// `weight_quant` format plus the norm vectors. The embedding table
    /// is excluded — decode gathers one embedding row per token, not
    /// the table — so this is the per-token weight-traffic floor
    /// (`cost::decode_weight_stream_s`), distinct from the resident
    /// footprint [`Qwen3Config::weight_bytes`].
    pub fn decode_stream_bytes(&self) -> u64 {
        let embedding = (self.vocab * self.hidden) as u64;
        let rest = self.param_count() - self.matrix_param_count() - embedding;
        self.matrix_weight_bytes() + rest * self.dtype.size_bytes() as u64
    }

    /// Per-token KV cache bytes.
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.layers * self.kv_heads * self.head_dim) as u64
            * self.dtype.size_bytes() as u64
    }

    /// Widest static partition the dense SPMD decode engine supports:
    /// the minimum across every dimension `parallel::splits` shards
    /// (columns of each projection, query heads, KV heads, intermediate
    /// width, vocab). `kv_heads` binds in practice — every other split
    /// dimension is a multiple of it. Worker counts beyond this width
    /// would get empty shards, so engine constructors clamp here.
    pub fn partition_width(&self) -> usize {
        let qdim = self.heads * self.head_dim;
        let kvdim = self.kv_heads * self.head_dim;
        self.kv_heads
            .min(self.heads)
            .min(self.hidden)
            .min(self.intermediate)
            .min(self.vocab)
            .min(qdim)
            .min(kvdim)
            .max(1)
    }
}

/// Names of the per-layer weight tensors.
fn wname(layer: usize, which: &str) -> String {
    format!("l{layer}.{which}")
}

/// Build one decode step (batch 1, one new token, `past` cached tokens)
/// as an IR graph. This is the graph every compiler phase consumes; for
/// the true 0.6B/1.7B scales pass `layers_limit` to keep e-graph passes
/// tractable (strategies replicate across identical layers).
pub fn decode_graph(cfg: &Qwen3Config, past: usize, layers_limit: Option<usize>) -> Graph {
    let mut g = Graph::new();
    let dt = cfg.dtype;
    let h = cfg.hidden;
    let hd = cfg.head_dim;
    let seq = past + 1;
    let layers = layers_limit.unwrap_or(cfg.layers).min(cfg.layers);

    // Current hidden state (embedding lookup happens outside the graph).
    let mut x = g.input("x", &[1, h], dt);
    for l in 0..layers {
        // ---- attention block ----
        let wn = g.constant(&wname(l, "attn_norm"), &[h], dt);
        let xn = g.add(Op::RmsNorm { eps_bits: cfg.rms_eps.to_bits() }, &[x, wn]);
        let wq = g.constant(&wname(l, "wq"), &[h, cfg.heads * hd], dt);
        let wk = g.constant(&wname(l, "wk"), &[h, cfg.kv_heads * hd], dt);
        let wv = g.constant(&wname(l, "wv"), &[h, cfg.kv_heads * hd], dt);
        let q = g.matmul(xn, wq);
        let k = g.matmul(xn, wk);
        let v = g.matmul(xn, wv);
        let q = g.add(Op::Rope { theta_bits: cfg.rope_theta.to_bits() }, &[q]);
        let k = g.add(Op::Rope { theta_bits: cfg.rope_theta.to_bits() }, &[k]);
        // The roped K and the V projection are written into the KV cache:
        // they are live graph outputs (the cache append is runtime state).
        g.mark_output(k);
        g.mark_output(v);
        // Scores against the cached K (past+1 positions).
        let kcache = g.input(&format!("l{l}.kcache"), &[cfg.kv_heads * hd, seq], dt);
        let vcache = g.input(&format!("l{l}.vcache"), &[seq, cfg.kv_heads * hd], dt);
        // GQA: query heads grouped over kv heads; modeled at graph level
        // as a single batched matmul over the flattened head dim.
        let qr = g.reshape(q, &[cfg.heads, 1, hd]);
        let kr = g.reshape(kcache, &[cfg.kv_heads, hd, seq]);
        // Repeat kv heads: modeled as slice-free broadcast matmul per
        // group; at the IR level we use kv_heads batches of the grouped
        // queries.
        let qg = g.reshape(qr, &[cfg.kv_heads, cfg.heads / cfg.kv_heads, hd]);
        let scores = g.matmul(qg, kr); // [kv, group, seq]
        let scale = g.add(Op::scalar(1.0 / (hd as f32).sqrt()), &[]);
        let scaled = g.binary(BinaryKind::Mul, scores, scale);
        let probs = g.softmax(scaled, 2);
        let vr = g.reshape(vcache, &[cfg.kv_heads, seq, hd]);
        let ctx = g.matmul(probs, vr); // [kv, group, hd]
        let ctx2 = g.reshape(ctx, &[1, cfg.heads * hd]);
        let wo = g.constant(&wname(l, "wo"), &[cfg.heads * hd, h], dt);
        let attn_out = g.matmul(ctx2, wo);
        let x1 = g.binary(BinaryKind::Add, x, attn_out);

        // ---- MLP block (SwiGLU) ----
        let wn2 = g.constant(&wname(l, "mlp_norm"), &[h], dt);
        let xn2 = g.add(Op::RmsNorm { eps_bits: cfg.rms_eps.to_bits() }, &[x1, wn2]);
        let wg = g.constant(&wname(l, "w_gate"), &[h, cfg.intermediate], dt);
        let wu = g.constant(&wname(l, "w_up"), &[h, cfg.intermediate], dt);
        let wd = g.constant(&wname(l, "w_down"), &[cfg.intermediate, h], dt);
        let gate = g.matmul(xn2, wg);
        let gate = g.unary(UnaryKind::Silu, gate);
        let up = g.matmul(xn2, wu);
        let prod = g.binary(BinaryKind::Mul, gate, up);
        let down = g.matmul(prod, wd);
        x = g.binary(BinaryKind::Add, x1, down);
    }
    // Final norm + LM head.
    let wn = g.constant("final_norm", &[h], dt);
    let xn = g.add(Op::RmsNorm { eps_bits: cfg.rms_eps.to_bits() }, &[x, wn]);
    let head = g.constant("lm_head", &[h, cfg.vocab], dt);
    let logits = g.matmul(xn, head);
    g.mark_output(logits);
    g
}

/// Real weights for the NTT execution backend (deterministic).
pub struct Qwen3Weights {
    pub cfg: Qwen3Config,
    pub embedding: Tensor,
    pub layers: Vec<LayerWeights>,
    pub final_norm: Tensor,
    pub lm_head: Tensor,
}

pub struct LayerWeights {
    pub attn_norm: Tensor,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub mlp_norm: Tensor,
    pub w_gate: Tensor,
    pub w_up: Tensor,
    pub w_down: Tensor,
}

impl Qwen3Weights {
    /// Initialize with scaled random normals (0.02 / sqrt(2*layers) for
    /// residual-path weights, standard GPT-style init).
    pub fn random(cfg: &Qwen3Config, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let h = cfg.hidden;
        let hd = cfg.head_dim;
        let s = 0.02f32;
        let so = s / (2.0 * cfg.layers as f32).sqrt();
        let layers = (0..cfg.layers)
            .map(|_| LayerWeights {
                attn_norm: Tensor::from_vec(&[h], vec![1.0; h]),
                wq: Tensor::randn(&[h, cfg.heads * hd], &mut rng, s),
                wk: Tensor::randn(&[h, cfg.kv_heads * hd], &mut rng, s),
                wv: Tensor::randn(&[h, cfg.kv_heads * hd], &mut rng, s),
                wo: Tensor::randn(&[cfg.heads * hd, h], &mut rng, so),
                mlp_norm: Tensor::from_vec(&[h], vec![1.0; h]),
                w_gate: Tensor::randn(&[h, cfg.intermediate], &mut rng, s),
                w_up: Tensor::randn(&[h, cfg.intermediate], &mut rng, s),
                w_down: Tensor::randn(&[cfg.intermediate, h], &mut rng, so),
            })
            .collect();
        Qwen3Weights {
            cfg: cfg.clone(),
            embedding: Tensor::randn(&[cfg.vocab, h], &mut rng, s),
            layers,
            final_norm: Tensor::from_vec(&[h], vec![1.0; h]),
            lm_head: Tensor::randn(&[h, cfg.vocab], &mut rng, s),
        }
    }

    /// The weight values a `quant`-mode engine actually multiplies by:
    /// every GEMM matrix round-tripped through its [`QuantMat`]
    /// (embedding and norms untouched; `F32` is a plain clone). The
    /// dense FCFS engine runs on these when `cfg.weight_quant` is
    /// quantized, so it stays the *bit-exact* differential oracle for
    /// the fused dequant-GEMM path — same f32 values (`QuantMat`
    /// decodes with the same expressions), same accumulation order.
    pub fn fake_quantized(&self, quant: WeightQuant) -> Qwen3Weights {
        let fq = |t: &Tensor| -> Tensor {
            if quant.is_quantized() {
                QuantMat::quantize(t, quant).dequantize()
            } else {
                t.clone()
            }
        };
        Qwen3Weights {
            cfg: self.cfg.clone(),
            embedding: self.embedding.clone(),
            layers: self
                .layers
                .iter()
                .map(|l| LayerWeights {
                    attn_norm: l.attn_norm.clone(),
                    wq: fq(&l.wq),
                    wk: fq(&l.wk),
                    wv: fq(&l.wv),
                    wo: fq(&l.wo),
                    mlp_norm: l.mlp_norm.clone(),
                    w_gate: fq(&l.w_gate),
                    w_up: fq(&l.w_up),
                    w_down: fq(&l.w_down),
                })
                .collect(),
            final_norm: self.final_norm.clone(),
            lm_head: fq(&self.lm_head),
        }
    }
}

impl Qwen3Weights {
    /// Load weights from `artifacts/weights.bin` (flat little-endian f32
    /// tensors in the order documented by python `model.weight_specs`:
    /// embedding, per layer [attn_norm, wq, wk, wv, wo, mlp_norm, w_gate,
    /// w_up, w_down], final_norm, lm_head). This is how the Rust NTT
    /// engine and the JAX-baked PJRT artifact share identical parameters.
    pub fn from_file(cfg: &Qwen3Config, path: &std::path::Path) -> std::io::Result<Self> {
        let bytes = std::fs::read(path)?;
        let mut off = 0usize;
        let mut take = |n: usize, dims: &[usize]| -> std::io::Result<Tensor> {
            let end = off + n * 4;
            if end > bytes.len() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    format!("weights.bin too short at offset {off}"),
                ));
            }
            let data: Vec<f32> = bytes[off..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            off = end;
            Ok(Tensor::from_vec(dims, data))
        };
        let h = cfg.hidden;
        let qd = cfg.heads * cfg.head_dim;
        let kvd = cfg.kv_heads * cfg.head_dim;
        let inter = cfg.intermediate;
        let embedding = take(cfg.vocab * h, &[cfg.vocab, h])?;
        let mut layers = Vec::with_capacity(cfg.layers);
        for _ in 0..cfg.layers {
            layers.push(LayerWeights {
                attn_norm: take(h, &[h])?,
                wq: take(h * qd, &[h, qd])?,
                wk: take(h * kvd, &[h, kvd])?,
                wv: take(h * kvd, &[h, kvd])?,
                wo: take(qd * h, &[qd, h])?,
                mlp_norm: take(h, &[h])?,
                w_gate: take(h * inter, &[h, inter])?,
                w_up: take(h * inter, &[h, inter])?,
                w_down: take(inter * h, &[inter, h])?,
            });
        }
        let final_norm = take(h, &[h])?;
        let lm_head = take(h * cfg.vocab, &[h, cfg.vocab])?;
        if off != bytes.len() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("weights.bin has {} trailing bytes", bytes.len() - off),
            ));
        }
        Ok(Qwen3Weights { cfg: cfg.clone(), embedding, layers, final_norm, lm_head })
    }
}

/// Interesting fusable subgraphs of the decode step for Auto Schedule:
/// returns the attention-core node set (scores → softmax → context).
pub fn attention_core_nodes(g: &Graph) -> Vec<NodeId> {
    // First softmax node and its matmul producer/consumer.
    for id in g.live_nodes() {
        if matches!(g.node(id).op, Op::Softmax { .. }) {
            let producer = g.node(id).inputs[0];
            // find matmul consumer
            let users = g.users();
            let consumer = users[id.index()]
                .iter()
                .find(|&&u| matches!(g.node(u).op, Op::MatMul))
                .copied();
            let mut v = vec![];
            // include the scores matmul feeding the scale
            let scale_in = g.node(producer).inputs[0];
            if matches!(g.node(scale_in).op, Op::MatMul) {
                v.push(scale_in);
            }
            v.push(id);
            if let Some(c) = consumer {
                v.push(c);
            }
            return v;
        }
    }
    vec![]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_scale_names() {
        let c06 = Qwen3Config::qwen3_0_6b(DType::F16);
        let n06 = c06.param_count();
        assert!(
            (500_000_000..800_000_000).contains(&n06),
            "0.6B params: {n06}"
        );
        let c17 = Qwen3Config::qwen3_1_7b(DType::F16);
        let n17 = c17.param_count();
        assert!(
            (1_400_000_000..2_200_000_000).contains(&n17),
            "1.7B params: {n17}"
        );
        let tiny = Qwen3Config::tiny();
        assert!(tiny.param_count() < 30_000_000);
    }

    #[test]
    fn partition_width_binds_at_kv_heads() {
        let tiny = Qwen3Config::tiny();
        assert_eq!(tiny.partition_width(), tiny.kv_heads);
        let c06 = Qwen3Config::qwen3_0_6b(DType::F16);
        assert_eq!(c06.partition_width(), 8);
    }

    #[test]
    fn f16_halves_weight_bytes() {
        let f32c = Qwen3Config::qwen3_0_6b(DType::F32);
        let f16c = Qwen3Config::qwen3_0_6b(DType::F16);
        assert_eq!(f32c.weight_bytes(), 2 * f16c.weight_bytes());
    }

    #[test]
    fn quantized_weight_bytes_shrink_the_footprint() {
        // F32 weight-quant must reproduce the seed accounting exactly
        // (the formula refactor is invisible until quantization is on).
        let f32c = Qwen3Config::tiny();
        assert_eq!(f32c.weight_bytes(), f32c.param_count() * 4);
        let i8c = Qwen3Config::tiny().with_weight_quant(WeightQuant::Int8);
        let i4c = Qwen3Config::tiny().with_weight_quant(WeightQuant::Int4);
        assert!(
            i8c.weight_bytes() < f32c.weight_bytes() / 2,
            "int8 must at least halve the footprint: {} vs {}",
            i8c.weight_bytes(),
            f32c.weight_bytes()
        );
        assert!(i4c.weight_bytes() < i8c.weight_bytes(), "int4 under int8");
        // Only the GEMM plane shrinks: embedding/norm bytes are shared.
        let rest = f32c.weight_bytes() - f32c.matrix_weight_bytes();
        assert_eq!(i8c.weight_bytes() - i8c.matrix_weight_bytes(), rest);
        // The matrix plane covers most of a real model's parameters.
        assert!(f32c.matrix_param_count() * 2 > f32c.param_count());
    }

    #[test]
    fn fake_quantized_perturbs_matrices_only() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 8);
        let fq = w.fake_quantized(WeightQuant::Int8);
        assert_eq!(fq.embedding.data, w.embedding.data, "embedding must stay exact");
        assert_eq!(fq.layers[0].attn_norm.data, w.layers[0].attn_norm.data);
        assert_ne!(fq.layers[0].wq.data, w.layers[0].wq.data, "wq must be perturbed");
        // ...but only within the per-group affine bound (loose check).
        let maxd = fq.layers[0]
            .wq
            .data
            .iter()
            .zip(&w.layers[0].wq.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(maxd < 1e-2, "int8 weight perturbation too large: {maxd}");
        // F32 is the identity.
        let id = w.fake_quantized(WeightQuant::F32);
        assert_eq!(id.layers[0].wq.data, w.layers[0].wq.data);
    }

    #[test]
    fn decode_graph_builds_and_types() {
        let cfg = Qwen3Config::tiny();
        let g = decode_graph(&cfg, 7, None);
        let out = g.node(*g.outputs.last().unwrap());
        assert_eq!(out.ty.shape.dims(), &[1, cfg.vocab]);
        // Graph contains the expected op mix.
        let live = g.live_nodes();
        let n_mm = live.iter().filter(|&&i| matches!(g.node(i).op, Op::MatMul)).count();
        assert_eq!(n_mm, cfg.layers * 9 + 1, "9 matmuls per layer + head");
        let n_sm =
            live.iter().filter(|&&i| matches!(g.node(i).op, Op::Softmax { .. })).count();
        assert_eq!(n_sm, cfg.layers);
    }

    #[test]
    fn layers_limit_truncates() {
        let cfg = Qwen3Config::qwen3_0_6b(DType::F32);
        let g1 = decode_graph(&cfg, 0, Some(1));
        let g28 = decode_graph(&cfg, 0, Some(2));
        assert!(g1.len() < g28.len());
    }

    #[test]
    fn attention_core_found() {
        let cfg = Qwen3Config::tiny();
        let g = decode_graph(&cfg, 3, Some(1));
        let core = attention_core_nodes(&g);
        assert_eq!(core.len(), 3, "scores matmul, softmax, context matmul");
        assert!(matches!(g.node(core[1]).op, Op::Softmax { .. }));
    }

    #[test]
    fn weights_deterministic() {
        let cfg = Qwen3Config::tiny();
        let a = Qwen3Weights::random(&cfg, 42);
        let b = Qwen3Weights::random(&cfg, 42);
        assert_eq!(a.layers[0].wq.data[..8], b.layers[0].wq.data[..8]);
        assert_eq!(a.layers.len(), cfg.layers);
    }
}
