//! Request serving: the FCFS oracle path and the continuous-batching
//! path over the paged KV pool, behind one front door —
//! [`Coordinator::serve`] with [`ServeOptions`].

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::Qwen3Engine;
use crate::cost::MachineSpec;
use crate::dist::ShardSpec;
use crate::obs::{json_escape, json_f64, Ring, TraceSummary, WorkerTrace};
use crate::serving::{
    BatchEngine, ContinuousConfig, ContinuousScheduler, FaultPlan, FaultReport,
    ServingMetrics, SpecSummary, StepSlot, TierConfig,
};
use crate::util::Stats;

/// Default per-track event-ring capacity of a traced serve
/// ([`ServeOptions::trace`]); override with the `PALLAS_TRACE_EVENTS`
/// env var. Rings are pre-allocated once per run and overwrite their
/// oldest events when full (`TraceSummary` reports the drop count), so
/// a too-small value degrades coverage, never correctness.
pub const DEFAULT_TRACE_EVENTS: usize = 65536;

/// Epoch restarts [`Coordinator::serve`] attempts after a poisoned SPMD
/// scope before giving up and resuming the original panic. Injected
/// failpoints are one-shot, so a healthy recovery converges in one
/// restart; a *recurring* panic is a real bug and must surface, not
/// loop forever.
const MAX_EPOCH_RECOVERIES: u32 = 3;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
}

/// How the coordinator schedules requests. Retained for the
/// deprecated [`Coordinator::serve_with_policy`] shim; new code passes
/// [`ServeOptions`] to [`Coordinator::serve`].
#[derive(Debug, Clone)]
pub enum ServePolicy {
    /// One request at a time over the dense per-request KV cache
    /// (batch size 1, §4's methodology). Kept as the differential
    /// oracle for the continuous path.
    Fcfs,
    /// Continuous batching over the paged KV block pool
    /// (`crate::serving`): iteration-level prefill+decode batching,
    /// prefix sharing, preemption-to-queue.
    Continuous(ContinuousConfig),
}

/// The scheduling mode of a [`ServeOptions`].
#[derive(Debug, Clone, Default)]
enum ServeMode {
    /// The FCFS differential oracle (batch-of-one dense engine).
    #[default]
    Fcfs,
    /// Continuous batching under an explicit config.
    Continuous(ContinuousConfig),
    /// Continuous batching under the serve-time autotune planner
    /// ([`ContinuousConfig::autotuned`]), resolved against the
    /// options' machine at serve time.
    Autotuned { max_batch: usize },
}

/// Everything [`Coordinator::serve`] needs to know about *how* to
/// serve: the scheduling mode plus cross-cutting overrides, validated
/// as a set. This is the single entry through which every serving knob
/// — including the `shards` knob of the sharded engine — lands once,
/// instead of being re-plumbed at each call site.
///
/// ```ignore
/// let rep = coordinator.serve(
///     &requests,
///     &ServeOptions::autotuned(8).threads(4).shards(2),
/// );
/// ```
#[derive(Debug, Clone, Default)]
pub struct ServeOptions {
    mode: ServeMode,
    threads: Option<usize>,
    prefill_chunk: Option<usize>,
    tiering: Option<TierConfig>,
    shards: Option<usize>,
    machine: Option<MachineSpec>,
    trace: bool,
    trace_out: Option<String>,
    deadline_ms: Option<u64>,
    max_queue: Option<usize>,
    faults: Option<FaultPlan>,
    spec_k: Option<usize>,
}

impl ServeOptions {
    /// Serve FCFS (the oracle path). Takes no overrides — the dense
    /// engine's shape is fixed at [`Qwen3Engine::new`].
    pub fn fcfs() -> Self {
        ServeOptions::default()
    }

    /// Continuous batching under an explicit [`ContinuousConfig`]
    /// (build one with [`ContinuousConfig::builder`]).
    pub fn continuous(cfg: ContinuousConfig) -> Self {
        ServeOptions { mode: ServeMode::Continuous(cfg), ..ServeOptions::default() }
    }

    /// Continuous batching under the serve-time autotune planner: the
    /// config is derived from the options' machine (default
    /// [`MachineSpec::ryzen_5900x`]) at serve time, and the chosen plan
    /// rides into the report.
    pub fn autotuned(max_batch: usize) -> Self {
        ServeOptions { mode: ServeMode::Autotuned { max_batch }, ..ServeOptions::default() }
    }

    /// Override the engine worker-thread count (continuous modes only).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    /// Override the prefill chunk (continuous modes only).
    pub fn prefill_chunk(mut self, prefill_chunk: usize) -> Self {
        self.prefill_chunk = Some(prefill_chunk);
        self
    }

    /// Attach a tiered KV store (continuous modes only).
    pub fn tiering(mut self, tiering: TierConfig) -> Self {
        self.tiering = Some(tiering);
        self
    }

    /// Shard the engine across `shards` cooperating worker groups
    /// (continuous modes only; 1 = explicitly unsharded). The
    /// per-matrix split-vs-broadcast layout is extracted from the dist
    /// cost model against the options' machine
    /// ([`ShardSpec::derive`]), recorded in the report's `sbp_sig`,
    /// and folded into an autotuned plan's hash. Outputs stay
    /// token-identical to FCFS at any value.
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = Some(shards);
        self
    }

    /// The machine model used to resolve autotuned configs and shard
    /// layouts (default [`MachineSpec::ryzen_5900x`]).
    pub fn machine(mut self, machine: MachineSpec) -> Self {
        self.machine = Some(machine);
        self
    }

    /// Record a per-worker phase timeline of the run (continuous modes
    /// only): every SPMD worker, the controller, and the scheduler log
    /// span events into pre-allocated rings (capacity
    /// [`DEFAULT_TRACE_EVENTS`] per track, `PALLAS_TRACE_EVENTS` env
    /// override), summarized into `ServeReport::trace`. Tracing records
    /// timestamps only — outputs are bitwise-identical to an untraced
    /// run (pinned by the differential tests in
    /// `rust/tests/serving.rs`); untraced runs pay one branch per hook.
    pub fn trace(mut self) -> Self {
        self.trace = true;
        self
    }

    /// As [`ServeOptions::trace`], and additionally write the merged
    /// timeline to `path` as Chrome-trace-event JSON — load it in
    /// Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`.
    pub fn trace_out(mut self, path: impl Into<String>) -> Self {
        self.trace = true;
        self.trace_out = Some(path.into());
        self
    }

    /// Per-request deadline in milliseconds (continuous modes only):
    /// requests that cannot finish in time are cancelled — queued or
    /// running — with their blocks released and any partial output
    /// kept, and dead-on-arrival submissions are rejected outright.
    /// Under deadline pressure the scheduler first halves the prefill
    /// chunk before shedding work. `0` rejects every request.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Bound the admission queue (continuous modes only): submissions
    /// beyond `max_queue` waiting requests are refused with a typed
    /// [`crate::serving::RejectReason`] — counted in the report's
    /// `faults.rejected` — instead of queued without bound.
    pub fn max_queue(mut self, max_queue: usize) -> Self {
        self.max_queue = Some(max_queue);
        self
    }

    /// Install a deterministic failpoint plan (continuous modes only)
    /// for chaos testing: seeded worker panics at a phase barrier,
    /// cold-tier fetch failures and payload corruption, transient block
    /// allocation failures ([`FaultPlan`]). An explicit plan wins over
    /// the `PALLAS_FAILPOINTS` env spec; the FCFS oracle path never
    /// injects, so differential tests always have a clean reference.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Enable self-drafting speculative decoding (continuous modes
    /// only): each decode sequence drafts up to `k` tokens from its own
    /// context by prompt lookup ([`crate::serving::spec`]), the engine
    /// verifies the whole draft in one span step, and commit keeps the
    /// longest matched causal prefix. Greedy acceptance keeps outputs
    /// token-identical to spec-off (and to the FCFS oracle) — this is a
    /// pure performance knob. `0` = explicitly off.
    pub fn spec_k(mut self, k: usize) -> Self {
        self.spec_k = Some(k);
        self
    }

    /// Check the option set; `Err` names the first violated rule.
    /// [`Coordinator::serve`] calls this (then the resolved config's
    /// own [`ContinuousConfig::validate`]) before any work runs.
    pub fn validate(&self) -> Result<(), String> {
        if matches!(self.mode, ServeMode::Fcfs) {
            if self.threads.is_some()
                || self.prefill_chunk.is_some()
                || self.tiering.is_some()
                || self.shards.is_some()
                || self.machine.is_some()
                || self.trace
                || self.deadline_ms.is_some()
                || self.max_queue.is_some()
                || self.faults.is_some()
                || self.spec_k.is_some()
            {
                return Err(
                    "FCFS takes no overrides (threads/prefill_chunk/tiering/shards/machine/\
                     trace/deadline_ms/max_queue/faults/spec_k apply to the continuous \
                     modes; the dense engine's shape is fixed at Qwen3Engine::new and the \
                     oracle path stays the unperturbed, non-speculative reference)"
                        .into(),
                );
            }
        }
        if self.max_queue == Some(0) {
            return Err("max_queue must be >= 1 (leave it unset for an unbounded queue)".into());
        }
        if let ServeMode::Autotuned { max_batch } = self.mode {
            if max_batch == 0 {
                return Err("autotuned max_batch must be > 0".into());
            }
        }
        if self.threads == Some(0) {
            return Err("threads override must be >= 1".into());
        }
        if self.shards == Some(0) {
            return Err("shards must be >= 1 (1 = unsharded)".into());
        }
        Ok(())
    }

    fn machine_or_default(&self) -> MachineSpec {
        self.machine.clone().unwrap_or_else(MachineSpec::ryzen_5900x)
    }

    /// Validate and resolve into the continuous config to run
    /// (`None` = FCFS): mode, then overrides, then the dist-extracted
    /// shard layout, then the resolved config's own invariants.
    fn resolve(
        &self,
        model: &crate::model::Qwen3Config,
    ) -> Result<Option<ContinuousConfig>, String> {
        self.validate()?;
        let mut cfg = match &self.mode {
            ServeMode::Fcfs => return Ok(None),
            ServeMode::Continuous(cfg) => cfg.clone(),
            ServeMode::Autotuned { max_batch } => {
                ContinuousConfig::autotuned(model, &self.machine_or_default(), *max_batch)
            }
        };
        if let Some(t) = self.threads {
            cfg.threads = t;
        }
        if let Some(c) = self.prefill_chunk {
            cfg.prefill_chunk = c;
        }
        if let Some(t) = &self.tiering {
            cfg.tiering = Some(t.clone());
        }
        if let Some(ms) = self.deadline_ms {
            cfg.deadline = Some(Duration::from_millis(ms));
        }
        if let Some(q) = self.max_queue {
            cfg.max_queue = q;
        }
        if let Some(k) = self.spec_k {
            cfg.spec_k = k;
        }
        match self.shards {
            Some(s) if s > 1 => {
                cfg.sharding = Some(ShardSpec::derive(model, &self.machine_or_default(), s));
            }
            Some(_) => cfg.sharding = None,
            None => {}
        }
        // A plan's hash must pin the layout the run executes, so two
        // runs under one hash served the same SBP signatures — and the
        // same speculative depth.
        if let Some(plan) = cfg.plan.as_mut() {
            match &cfg.sharding {
                Some(s) => {
                    plan.shards = s.shards;
                    plan.sbp_sig = s.sig();
                }
                None => {
                    plan.shards = 1;
                    plan.sbp_sig = "-".into();
                }
            }
            plan.spec_k = cfg.spec_k;
        }
        cfg.validate()?;
        Ok(Some(cfg))
    }
}

/// Aggregate serving metrics.
#[derive(Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    /// Effective SPMD worker threads of the decode engine that served
    /// this run (after clamping: partition width for FCFS, batch width
    /// for continuous) — outputs are identical at any value, so this is
    /// a performance annotation, not a result descriptor.
    pub threads: usize,
    /// Weight-plane storage mode of the run (`Qwen3Config::weight_quant`
    /// — unlike `threads`, a quantized mode *is* a result descriptor:
    /// int8/int4 runs may diverge from the f32 oracle within the
    /// documented error bound).
    pub weight_quant: crate::ntt::WeightQuant,
    /// Resident model weight footprint in the `weight_quant` format
    /// (`Qwen3Config::weight_bytes`, embedding included): what
    /// `kv_block_budget` callers reserve out of machine memory. For the
    /// per-token weight *traffic* (embedding excluded — it is gathered,
    /// not streamed) see `Qwen3Config::decode_stream_bytes`.
    pub weight_bytes: u64,
    pub wall_s: f64,
    /// Decode throughput over the decode-timed tokens only, computed
    /// from directly accumulated decode seconds (never `mean * count`).
    pub decode_tokens_per_s: f64,
    /// Prefill throughput (prompt positions per second) over directly
    /// accumulated prefill seconds. Chunked prefill
    /// (`ContinuousConfig::prefill_chunk`) moves this toward the
    /// compute roofline (`cost::prefill_flops_s`); FCFS measures its
    /// per-request prompt loops.
    pub prefill_tok_s: f64,
    /// Per-token decode latency stats (seconds).
    pub token_latency: Stats,
    /// Time-to-first-token per request, seconds, measured from
    /// submission (= the start of the serve call, when the whole batch
    /// arrives) to the first sampled token. Queue / head-of-line wait
    /// is included under both policies, so the field is comparable
    /// across them — FCFS tail requests rightly show the wait behind
    /// earlier generations.
    pub ttft: Stats,
    /// Per-request end-to-end latency stats (seconds), measured from
    /// submission (= serve start) to completion under both policies, so
    /// FCFS head-of-line wait is included just as queue wait is for the
    /// continuous path.
    pub request_latency: Stats,
    /// Generated token ids per request.
    pub outputs: Vec<(u64, Vec<usize>)>,
    /// Tier configuration of the run (`TierConfig::describe`); `None`
    /// for FCFS and for the flat (untiered) continuous path.
    pub tier: Option<String>,
    /// The serve plan of an autotuned continuous run
    /// (`ContinuousConfig::autotuned`): plan hash + chosen knobs.
    /// `None` for FCFS and manually-configured runs. Like `threads`, a
    /// pure performance annotation — outputs are identical with or
    /// without a plan.
    pub plan: Option<crate::serving::ServePlan>,
    /// Shard groups of the engine run (1 = unsharded / FCFS). Like
    /// `threads`, a pure performance annotation: outputs are bitwise
    /// identical at any value.
    pub shards: usize,
    /// The dist-extracted per-matrix SBP signature of a sharded run
    /// (`ShardSpec::sig`, e.g. `"wq=S(1),...,lm_head=B"`) — recorded
    /// verbatim so a report proves *which* layout the cost model chose,
    /// not just that sharding was on. `None` for FCFS and unsharded
    /// runs.
    pub sbp_sig: Option<String>,
    /// Speculative-decoding accounting of a continuous run with
    /// `spec_k > 0` ([`ServeOptions::spec_k`]): drafted / accepted /
    /// rejected totals plus the accept rate and the accepted-tokens-
    /// per-decode-step ratio (> 1.0 means decode finished in fewer
    /// engine iterations than tokens emitted). `None` for FCFS and for
    /// spec-off continuous runs, mirroring `faults`.
    pub spec: Option<SpecSummary>,
    /// Extended metrics of the continuous-batching path (None for FCFS).
    pub serving: Option<ServingMetrics>,
    /// Fault/robustness accounting of a continuous run: failpoints
    /// injected, epoch restarts, sequences requeued by recovery,
    /// requests rejected by admission backpressure, deadlines missed.
    /// All-zero on a healthy run; `None` for FCFS (the oracle path
    /// neither injects nor recovers).
    pub faults: Option<FaultReport>,
    /// Phase/utilization summary of a traced run
    /// ([`ServeOptions::trace`]): per-phase time breakdown with
    /// barrier-wait attribution and per-worker busy/wait split. `None`
    /// when tracing is off (the default) and for FCFS.
    pub trace: Option<TraceSummary>,
}

impl ServeReport {
    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} prompt_toks={} gen_toks={} threads={} weights={}/{} wall={:.2}s \
             decode={:.2} tok/s prefill={:.2} tok/s ttft p50={:.2}ms p99={:.2}ms \
             tok_lat p50={:.2}ms p99={:.2}ms req_lat mean={:.2}s",
            self.requests,
            self.prompt_tokens,
            self.generated_tokens,
            self.threads,
            crate::util::human_bytes(self.weight_bytes as usize),
            self.weight_quant.name(),
            self.wall_s,
            self.decode_tokens_per_s,
            self.prefill_tok_s,
            self.ttft.percentile(50.0) * 1e3,
            self.ttft.percentile(99.0) * 1e3,
            self.token_latency.percentile(50.0) * 1e3,
            self.token_latency.percentile(99.0) * 1e3,
            self.request_latency.mean(),
        );
        if self.shards > 1 {
            s.push_str(&format!(
                " shards={} sbp[{}]",
                self.shards,
                self.sbp_sig.as_deref().unwrap_or("-")
            ));
        }
        if let Some(t) = &self.tier {
            s.push_str(&format!(" tier[{t}]"));
        }
        if let Some(p) = &self.plan {
            s.push_str(&format!(" plan[{}]", p.render()));
        }
        // Predicted-vs-measured: the plan's roofline per-iteration cost
        // estimates against what the run actually measured (decode-only
        // iterations are directly comparable to the decode roofline;
        // prefill-carrying ones to the prefill roofline).
        if let (Some(p), Some(m)) = (&self.plan, &self.serving) {
            if m.decode_only_iters > 0 {
                s.push_str(&format!(
                    " pred/meas[decode {:.3}/{:.3}ms",
                    p.predicted_decode_iter_s * 1e3,
                    m.decode_iter_mean_s() * 1e3,
                ));
                if m.prefill_iters > 0 {
                    s.push_str(&format!(
                        " prefill {:.3}/{:.3}ms",
                        p.predicted_prefill_iter_s * 1e3,
                        m.prefill_iter_mean_s() * 1e3,
                    ));
                }
                s.push(']');
            }
        }
        if let Some(m) = &self.serving {
            s.push_str(&format!(" | {}", m.render()));
        }
        if let Some(f) = &self.faults {
            if f.any() {
                s.push_str(&format!(
                    " | faults injected={} recovered={} requeued={} rejected={} missed={}",
                    f.injected, f.recovered, f.requeued, f.rejected, f.deadline_missed,
                ));
            }
        }
        if let Some(t) = &self.trace {
            s.push_str(&format!(" | trace[{}]", t.render()));
        }
        s
    }

    /// The machine-readable report: stable-key-order JSON built by hand
    /// (no serializer dependency) — the one schema `benches/serve.rs`,
    /// `tools/bench_compare.py` and the CI bench-smoke job consume
    /// (`repro serve --report-json`). Every number goes through
    /// [`json_f64`] so the output is always valid JSON (non-finite
    /// values degrade to 0.0); nullable sections (`sbp_sig`, `plan`,
    /// `tier`, `serving`, `faults`, `spec`, `trace`) are emitted as
    /// JSON `null` so readers see one shape regardless of mode.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        fn int(o: &mut String, k: &str, v: u64) {
            let _ = write!(o, ",\"{k}\":{v}");
        }
        fn num(o: &mut String, k: &str, v: f64) {
            let _ = write!(o, ",\"{k}\":{}", json_f64(v));
        }
        let mut o = String::from("{\"schema\":\"serve_report.v1\"");
        int(&mut o, "requests", self.requests as u64);
        int(&mut o, "prompt_tokens", self.prompt_tokens as u64);
        int(&mut o, "generated_tokens", self.generated_tokens as u64);
        int(&mut o, "threads", self.threads as u64);
        int(&mut o, "shards", self.shards as u64);
        let _ = write!(o, ",\"weight_quant\":\"{}\"", json_escape(self.weight_quant.name()));
        int(&mut o, "weight_bytes", self.weight_bytes);
        num(&mut o, "wall_s", self.wall_s);
        num(&mut o, "decode_tok_s", self.decode_tokens_per_s);
        num(&mut o, "prefill_tok_s", self.prefill_tok_s);
        num(&mut o, "ttft_p50_s", self.ttft.percentile(50.0));
        num(&mut o, "ttft_p99_s", self.ttft.p99());
        num(&mut o, "tpot_p50_s", self.token_latency.percentile(50.0));
        num(&mut o, "tpot_p99_s", self.token_latency.p99());
        num(&mut o, "request_p50_s", self.request_latency.percentile(50.0));
        num(&mut o, "request_p99_s", self.request_latency.p99());
        match &self.sbp_sig {
            Some(sig) => {
                let _ = write!(o, ",\"sbp_sig\":\"{}\"", json_escape(sig));
            }
            None => o.push_str(",\"sbp_sig\":null"),
        }
        match &self.plan {
            Some(p) => {
                let _ = write!(o, ",\"plan\":{{\"hash\":\"{:016x}\"", p.plan_hash());
                int(&mut o, "max_batch", p.max_batch as u64);
                int(&mut o, "block_size", p.block_size as u64);
                int(&mut o, "num_blocks", p.num_blocks as u64);
                int(&mut o, "threads", p.decode_threads as u64);
                int(&mut o, "prefill_chunk", p.prefill_chunk as u64);
                int(&mut o, "step_token_budget", p.step_token_budget as u64);
                int(&mut o, "panel_rows", p.panel_rows as u64);
                num(&mut o, "predicted_decode_iter_s", p.predicted_decode_iter_s);
                num(&mut o, "predicted_prefill_iter_s", p.predicted_prefill_iter_s);
                o.push('}');
            }
            None => o.push_str(",\"plan\":null"),
        }
        match &self.tier {
            Some(t) => {
                let _ = write!(o, ",\"tier\":\"{}\"", json_escape(t));
            }
            None => o.push_str(",\"tier\":null"),
        }
        match &self.serving {
            Some(m) => {
                let _ = write!(o, ",\"serving\":{{\"iterations\":{}", m.iterations);
                int(&mut o, "decode_steps", m.decode_steps as u64);
                int(&mut o, "prefill_steps", m.prefill_steps as u64);
                int(&mut o, "replay_steps", m.replay_steps as u64);
                int(&mut o, "preemptions", m.preemptions as u64);
                int(&mut o, "prefix_hits", m.prefix_hits as u64);
                int(&mut o, "decode_only_iters", m.decode_only_iters as u64);
                num(&mut o, "decode_iter_mean_s", m.decode_iter_mean_s());
                int(&mut o, "prefill_iters", m.prefill_iters as u64);
                num(&mut o, "prefill_iter_mean_s", m.prefill_iter_mean_s());
                num(&mut o, "request_e2e_p50_s", m.request_e2e.percentile(50.0));
                num(&mut o, "request_e2e_p99_s", m.request_e2e.p99());
                int(&mut o, "swap_preemptions", m.swap_preemptions as u64);
                int(&mut o, "recompute_preemptions", m.recompute_preemptions as u64);
                int(&mut o, "spills", m.spills as u64);
                int(&mut o, "fetches", m.fetches as u64);
                int(&mut o, "spill_bytes", m.spill_bytes);
                int(&mut o, "fetch_bytes", m.fetch_bytes);
                o.push('}');
            }
            None => o.push_str(",\"serving\":null"),
        }
        match &self.faults {
            Some(f) => {
                let _ = write!(o, ",\"faults\":{{\"injected\":{}", f.injected);
                int(&mut o, "recovered", f.recovered as u64);
                int(&mut o, "requeued", f.requeued as u64);
                int(&mut o, "rejected", f.rejected as u64);
                int(&mut o, "deadline_missed", f.deadline_missed as u64);
                o.push('}');
            }
            None => o.push_str(",\"faults\":null"),
        }
        match &self.spec {
            Some(s) => {
                let _ = write!(o, ",\"spec\":{{\"spec_k\":{}", s.spec_k);
                int(&mut o, "steps", s.steps as u64);
                int(&mut o, "drafted", s.drafted as u64);
                int(&mut o, "accepted", s.accepted as u64);
                int(&mut o, "rejected", s.rejected as u64);
                num(&mut o, "accept_rate", s.accept_rate);
                num(&mut o, "accepted_tokens_per_step", s.accepted_tokens_per_step);
                o.push('}');
            }
            None => o.push_str(",\"spec\":null"),
        }
        match &self.trace {
            Some(t) => {
                let _ = write!(o, ",\"trace\":{}", t.to_json());
            }
            None => o.push_str(",\"trace\":null"),
        }
        o.push('}');
        o
    }
}

/// The serving coordinator.
pub struct Coordinator {
    pub engine: Qwen3Engine,
}

impl Coordinator {
    pub fn new(engine: Qwen3Engine) -> Self {
        Coordinator { engine }
    }

    /// Serve a list of requests to completion — the single serving
    /// entry. `opts` picks the mode (FCFS oracle, explicit continuous
    /// config, or autotuned) and carries every cross-cutting override
    /// (threads, chunk, tiering, shards, machine); it is validated as a
    /// set before any work runs, and an invalid combination panics with
    /// the violated rule (serve setup should fail loudly, not steps
    /// later).
    pub fn serve(&mut self, requests: &[Request], opts: &ServeOptions) -> ServeReport {
        let resolved = opts
            .resolve(self.engine.cfg())
            .unwrap_or_else(|e| panic!("invalid ServeOptions: {e}"));
        match resolved {
            None => self.serve_fcfs(requests),
            Some(cfg) => self.serve_continuous(requests, cfg, opts),
        }
    }

    /// Serve a list of requests under `policy`.
    #[deprecated(note = "use Coordinator::serve with ServeOptions")]
    pub fn serve_with_policy(&mut self, requests: &[Request], policy: ServePolicy) -> ServeReport {
        match policy {
            ServePolicy::Fcfs => self.serve(requests, &ServeOptions::fcfs()),
            ServePolicy::Continuous(cfg) => self.serve(requests, &ServeOptions::continuous(cfg)),
        }
    }

    fn serve_fcfs(&mut self, requests: &[Request]) -> ServeReport {
        let wall = Instant::now();
        let mut token_latency = Stats::default();
        let mut ttft = Stats::default();
        let mut request_latency = Stats::default();
        let mut outputs = Vec::new();
        let mut prompt_tokens = 0usize;
        let mut generated = 0usize;
        // Decode seconds accumulated directly (the old report derived
        // them back from `mean * count`, and sampled the first token's
        // latency outside any timing window).
        let mut decode_s = 0.0f64;
        let mut decode_steps = 0usize;
        // Prefill seconds accumulated directly around each request's
        // prompt loop (FCFS ingests prompts one token at a time — the
        // bandwidth-bound baseline the chunked continuous path beats).
        let mut prefill_s = 0.0f64;
        for req in requests {
            self.engine.reset();
            let mut pos = 0usize;
            let mut logits = Vec::new();
            let t_prefill = Instant::now();
            for &tok in &req.prompt {
                logits = self.engine.decode_step(tok, pos);
                pos += 1;
            }
            prefill_s += t_prefill.elapsed().as_secs_f64();
            prompt_tokens += req.prompt.len();
            let mut toks = Vec::with_capacity(req.max_new_tokens);
            if req.max_new_tokens > 0 && !req.prompt.is_empty() {
                // First token: sampled from the prompt's final logits,
                // inside the TTFT window (from serve start, so FCFS
                // head-of-line wait is visible, as in the continuous
                // path).
                let mut next = super::engine::argmax(&logits);
                ttft.push(wall.elapsed().as_secs_f64());
                toks.push(next);
                generated += 1;
                // Remaining tokens: each decode step timed directly. The
                // old loop also ran one extra step whose logits were
                // discarded; stop at the last sampled token instead.
                for _ in 1..req.max_new_tokens {
                    let t_tok = Instant::now();
                    logits = self.engine.decode_step(next, pos);
                    pos += 1;
                    next = super::engine::argmax(&logits);
                    let dt = t_tok.elapsed().as_secs_f64();
                    token_latency.push(dt);
                    decode_s += dt;
                    decode_steps += 1;
                    toks.push(next);
                    generated += 1;
                }
            }
            // From serve start, like the continuous path (see the field
            // doc): the wait behind earlier requests is part of this
            // request's latency.
            request_latency.push(wall.elapsed().as_secs_f64());
            outputs.push((req.id, toks));
        }
        let wall_s = wall.elapsed().as_secs_f64();
        ServeReport {
            requests: requests.len(),
            prompt_tokens,
            generated_tokens: generated,
            threads: self.engine.threads,
            weight_quant: self.engine.cfg().weight_quant,
            weight_bytes: self.engine.cfg().weight_bytes(),
            wall_s,
            decode_tokens_per_s: if decode_s > 0.0 { decode_steps as f64 / decode_s } else { 0.0 },
            prefill_tok_s: if prefill_s > 0.0 { prompt_tokens as f64 / prefill_s } else { 0.0 },
            token_latency,
            ttft,
            request_latency,
            outputs,
            tier: None,
            plan: None,
            shards: 1,
            sbp_sig: None,
            spec: None,
            serving: None,
            faults: None,
            trace: None,
        }
    }

    fn serve_continuous(
        &mut self,
        requests: &[Request],
        cfg: ContinuousConfig,
        opts: &ServeOptions,
    ) -> ServeReport {
        let wall = Instant::now();
        // Step capacity in token rows: the scheduler's per-iteration
        // budget (== max_batch when prefill_chunk is 1, so the seed
        // behaviour is byte-identical).
        let max_rows = cfg.row_capacity();
        // Effective worker count (the engine applies the same clamp;
        // computed here so the report records what actually ran).
        let threads = cfg.threads.clamp(1, max_rows);
        let tier_desc = cfg.tiering.as_ref().map(|t| t.describe());
        let mut sched = ContinuousScheduler::new(cfg.clone());
        let mut be = BatchEngine::new(&self.engine.weights, cfg.num_blocks, cfg.block_size);
        if let Some(p) = &cfg.plan {
            // The one plan knob the config fields cannot carry: the
            // GEMM shard granularity (bitwise-neutral, MR-grid).
            be.set_panel_rows(p.panel_rows);
        }
        // The dist-extracted shard layout: the run then spawns
        // `shards × threads` workers (bitwise-neutral, see the engine
        // module docs).
        let (shards, sbp_sig) = match &cfg.sharding {
            Some(s) if s.is_sharded() => {
                be.set_sharding(*s);
                (s.shards, Some(s.sig()))
            }
            _ => (1, None),
        };
        if let Some(t) = &cfg.tiering {
            let model = &self.engine.weights.cfg;
            sched.set_tier_geometry(model.layers, model.kv_heads * model.head_dim);
            be.enable_tier(t.cold_blocks, t.quant);
        }
        // Failpoints: an explicit plan on the options wins; otherwise
        // the PALLAS_FAILPOINTS env spec (lenient parse — malformed
        // degrades to unfaulted with one warning). One Arc is shared by
        // the engine's barrier/tier hooks, the scheduler's admission
        // hook, and this loop's report. `None` — the overwhelmingly
        // common case — keeps every hook a single untaken branch.
        let faults: Option<Arc<FaultPlan>> = opts
            .faults
            .clone()
            .or_else(FaultPlan::from_env)
            .filter(|p| !p.is_empty())
            .map(Arc::new);
        be.set_faults(faults.clone());
        sched.set_faults(faults.clone());
        // Tracing: one shared epoch for every ring (the SPMD workers'
        // and the scheduler's) so all timelines merge onto one time
        // axis. Capacity is per track; the rings overwrite their oldest
        // events when full, so the knob bounds memory, not run length.
        let trace_cfg = opts.trace.then(|| {
            let cap = crate::util::env_knob("PALLAS_TRACE_EVENTS", |v: &usize| *v > 0)
                .unwrap_or(DEFAULT_TRACE_EVENTS);
            (Instant::now(), cap)
        });
        if let Some((epoch, cap)) = trace_cfg {
            sched.set_trace(Ring::with_capacity(cap, epoch));
        }
        for r in requests {
            sched.submit(r);
        }
        let mut request_latency = Stats::default();
        let mut done: HashMap<u64, Vec<usize>> = HashMap::new();
        // One SPMD run per *epoch* — the workers are spawned once and
        // parked between iterations, so the per-step cost is one barrier
        // release instead of a spawn/join per step. A panic anywhere in
        // the scope (a worker or the driver, injected or real) poisons
        // the barrier and unwinds out of `run_traced`; the epoch loop
        // catches it here, at a committed boundary: interrupted
        // iterations never called `commit`, so rolling every in-flight
        // sequence back to its committed KV position and requeuing it
        // (`recover_after_panic`, which also audits the pool for leaked
        // blocks) replays to token-identical outputs — greedy argmax is
        // per-request deterministic, so batching composition cannot
        // change tokens. Bounded retries: a *recurring* panic is a real
        // bug and resumes instead of looping. On a traced run the
        // timeline covers the final (successful) epoch — a poisoned
        // epoch's rings unwind with its scope.
        let mut recovered_epochs = 0u32;
        let log = loop {
            let epoch = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                be.run_traced(threads, max_rows, trace_cfg, |stepper| {
                    while !sched.is_done() {
                        let scheduled = sched.schedule();
                        // Without failpoints, schedule() either yields at
                        // least one runnable sequence or panics (pool too
                        // small for the queue head); an injected transient
                        // allocation failure may instead defer every
                        // admission for one iteration.
                        debug_assert!(
                            scheduled > 0 || faults.is_some(),
                            "scheduler yielded no work while not done"
                        );
                        if scheduled == 0 {
                            sched.commit(&[], 0.0);
                            continue;
                        }
                        // Tier traffic first: spills/fetches move KV
                        // across the storage boundary before the step
                        // reads or overwrites the affected blocks.
                        // Fetches whose payload fails checksum
                        // verification (or draws an injected transient
                        // failure) come back as bad slots — and so do
                        // direct-read resumes whose in-place cold audit
                        // fails.
                        let ops = sched.take_tier_ops();
                        let mut bad = stepper.tier_ops(&ops);
                        bad.extend(stepper.verify_cold(&sched.resume_audits()));
                        if !bad.is_empty() {
                            // Reclassify the owners swap → recompute and
                            // re-plan the iteration without them: their
                            // KV is rebuilt from the prompt, never served
                            // from a corrupt payload.
                            sched.fault_cold(&bad);
                            continue;
                        }
                        let t_iter = Instant::now();
                        let slots: Vec<StepSlot> = sched
                            .running()
                            .iter()
                            .map(|s| StepSlot {
                                tokens: &s.tokens[s.pos..s.pos + s.span],
                                pos: s.pos,
                                table: &s.table.blocks,
                                cold: &s.cold,
                                sample: s.span_reaches_frontier(),
                            })
                            .collect();
                        // Speculative runs read the argmax of every row
                        // (spec rows carry drafts to verify); plain runs
                        // sample only span-final frontier rows. Both
                        // readouts happen after the same final barrier,
                        // so both are bitwise across threads x shards.
                        if cfg.spec_k > 0 {
                            let rows = stepper.step_verify(&slots);
                            drop(slots);
                            sched.commit_verified(&rows, t_iter.elapsed().as_secs_f64());
                        } else {
                            let samples = stepper.step(&slots);
                            drop(slots);
                            sched.commit(&samples, t_iter.elapsed().as_secs_f64());
                        }
                        for f in sched.take_finished() {
                            request_latency.push(wall.elapsed().as_secs_f64());
                            done.insert(f.id, f.generated);
                        }
                    }
                })
            }));
            match epoch {
                Ok(((), log)) => break log,
                Err(payload) => {
                    if recovered_epochs >= MAX_EPOCH_RECOVERIES {
                        std::panic::resume_unwind(payload);
                    }
                    recovered_epochs += 1;
                    sched.recover_after_panic();
                }
            }
        };
        // Degenerate requests (empty prompt / zero budget) finish at
        // submit time without ever entering the loop.
        for f in sched.take_finished() {
            request_latency.push(wall.elapsed().as_secs_f64());
            done.insert(f.id, f.generated);
        }
        // Merge the engine timelines with the scheduler's own track,
        // export the Chrome trace if asked, and fold the whole log into
        // the report's summary.
        let trace = log.map(|mut log| {
            if let Some(r) = sched.take_trace() {
                log.workers.push(WorkerTrace {
                    tid: log.workers.len() as u32,
                    name: "scheduler".into(),
                    events: r.events(),
                    dropped: r.dropped(),
                });
            }
            if let Some(path) = &opts.trace_out {
                std::fs::write(path, log.to_chrome_json())
                    .unwrap_or_else(|e| panic!("failed to write trace to {path}: {e}"));
            }
            TraceSummary::from_log(&log)
        });

        let metrics = std::mem::take(&mut sched.metrics);
        // Fault ledger: injection counts come straight off the plan's
        // atomic counters, recovery counts off the epoch loop, and the
        // request-level counters off the scheduler metrics. Always
        // `Some` on the continuous path (all-zero on a calm run) so the
        // JSON shape is stable; the FCFS oracle reports `None`.
        let fault_report = FaultReport {
            injected: faults.as_ref().map_or(0, |p| p.injected()),
            recovered: recovered_epochs,
            requeued: metrics.fault_requeued as u32,
            rejected: metrics.rejected as u32,
            deadline_missed: metrics.deadline_missed as u32,
        };
        let outputs: Vec<(u64, Vec<usize>)> = requests
            .iter()
            .map(|r| (r.id, done.remove(&r.id).unwrap_or_default()))
            .collect();
        // Snapshot the speculative summary before `metrics` moves into
        // the report; `None` whenever spec was off, mirroring `faults`
        // on the FCFS side.
        let spec = metrics.spec_summary(cfg.spec_k);
        ServeReport {
            requests: requests.len(),
            prompt_tokens: requests.iter().map(|r| r.prompt.len()).sum(),
            generated_tokens: outputs.iter().map(|(_, t)| t.len()).sum(),
            threads,
            weight_quant: self.engine.cfg().weight_quant,
            weight_bytes: self.engine.cfg().weight_bytes(),
            wall_s: wall.elapsed().as_secs_f64(),
            decode_tokens_per_s: metrics.decode_tokens_per_s(),
            prefill_tok_s: metrics.prefill_tokens_per_s(),
            token_latency: metrics.tpot.clone(),
            ttft: metrics.ttft.clone(),
            request_latency,
            outputs,
            tier: tier_desc,
            plan: cfg.plan.clone(),
            shards,
            sbp_sig,
            spec,
            serving: Some(metrics),
            faults: Some(fault_report),
            trace,
        }
    }
}

/// Build a deterministic synthetic workload (`n` requests with pseudo-
/// random prompts over the model vocab).
pub fn synthetic_workload(
    n: usize,
    prompt_len: usize,
    max_new: usize,
    vocab: usize,
) -> Vec<Request> {
    let mut rng = crate::util::Rng::new(0xBEEF);
    (0..n)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..prompt_len).map(|_| rng.below(vocab)).collect(),
            max_new_tokens: max_new,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Qwen3Config, Qwen3Weights};

    #[test]
    fn serves_and_reports() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 2, 64));
        let reqs = synthetic_workload(3, 4, 5, cfg.vocab);
        let rep = c.serve(&reqs, &ServeOptions::fcfs());
        assert_eq!(rep.requests, 3);
        assert_eq!(rep.generated_tokens, 15);
        assert_eq!(rep.prompt_tokens, 12);
        assert!(rep.decode_tokens_per_s > 0.0);
        assert!(rep.prefill_tok_s > 0.0, "FCFS must time its prompt loops");
        assert_eq!(rep.outputs.len(), 3);
        assert!(rep.render().contains("tok/s"));
        assert!(rep.render().contains("prefill="), "{}", rep.render());
        assert!(rep.render().contains("ttft p50="), "{}", rep.render());
        assert!(rep.render().contains("p99="), "{}", rep.render());
        // Satellite fix: first-token latency is captured (TTFT window)
        // and decode seconds come from direct accumulation.
        assert_eq!(rep.ttft.len(), 3);
        assert_eq!(rep.token_latency.len(), 3 * 4, "max_new-1 timed steps per request");
        assert!(rep.serving.is_none());
        assert_eq!(rep.threads, 2, "FCFS report records the dense engine's threads");
        assert!(rep.render().contains("threads=2"));
        // Weight footprint + quant mode are surfaced in the report.
        assert_eq!(rep.weight_quant, crate::ntt::WeightQuant::F32);
        assert_eq!(rep.weight_bytes, cfg.weight_bytes());
        assert!(rep.render().contains("weights="), "{}", rep.render());
        assert!(rep.render().contains("/f32"), "{}", rep.render());
    }

    #[test]
    fn quantized_run_is_recorded_in_report() {
        use crate::ntt::WeightQuant;
        let cfg = Qwen3Config::tiny().with_weight_quant(WeightQuant::Int8);
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(2, 4, 3, cfg.vocab);
        for opts in [
            ServeOptions::fcfs(),
            ServeOptions::continuous(ContinuousConfig::default()),
        ] {
            let rep = c.serve(&reqs, &opts);
            assert_eq!(rep.weight_quant, WeightQuant::Int8);
            assert_eq!(rep.weight_bytes, cfg.weight_bytes());
            assert!(rep.render().contains("/int8"), "{}", rep.render());
            assert_eq!(rep.generated_tokens, 6, "quantized runs must still finish");
        }
    }

    #[test]
    fn workload_deterministic() {
        let a = synthetic_workload(2, 3, 4, 100);
        let b = synthetic_workload(2, 3, 4, 100);
        assert_eq!(a[0].prompt, b[0].prompt);
        assert_eq!(a[1].prompt, b[1].prompt);
        assert_ne!(a[0].prompt, a[1].prompt);
    }

    #[test]
    fn continuous_policy_reports() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(3, 4, 5, cfg.vocab);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(32)
            .max_batch(3)
            .threads(2)
            .build();
        let rep = c.serve(&reqs, &ServeOptions::continuous(ccfg));
        assert_eq!(rep.requests, 3);
        assert_eq!(rep.threads, 2, "report must record the effective worker count");
        assert_eq!(rep.generated_tokens, 15);
        assert_eq!(rep.outputs.len(), 3);
        let m = rep.serving.as_ref().expect("continuous metrics");
        assert!(m.iterations > 0);
        assert!(m.batch_size.max() >= 2.0, "requests must actually batch");
        assert!(rep.render().contains("batch mean"));
        assert!(rep.tier.is_none(), "flat pool runs carry no tier descriptor");
        assert!(!rep.render().contains("tier["));
        assert!(rep.plan.is_none(), "manual configs carry no plan");
        assert!(!rep.render().contains("plan["));
        assert_eq!(rep.shards, 1, "unsharded runs report one group");
        assert!(rep.sbp_sig.is_none());
        assert!(!rep.render().contains("sbp["));
    }

    #[test]
    fn autotuned_run_records_its_plan() {
        let cfg = Qwen3Config::tiny();
        let machine = crate::cost::MachineSpec::ryzen_5900x();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(3, 4, 5, cfg.vocab);
        let ccfg = ContinuousConfig::autotuned(&cfg, &machine, 3);
        let plan = ccfg.plan.clone().expect("autotuned config carries its plan");
        let rep = c.serve(&reqs, &ServeOptions::autotuned(3).machine(machine));
        assert_eq!(rep.generated_tokens, 15, "autotuned serve must still finish");
        let got = rep.plan.as_ref().expect("report must record the plan");
        assert_eq!(got, &plan);
        let r = rep.render();
        assert!(r.contains("plan["), "{r}");
        assert!(r.contains(&format!("{:#018x}", plan.plan_hash())), "{r}");
        assert!(r.contains(&format!("chunk={}", plan.prefill_chunk)), "{r}");
        // Predicted-vs-measured: an autotuned run that ran decode-only
        // iterations renders the plan's roofline estimate next to the
        // measured mean.
        let m = rep.serving.as_ref().unwrap();
        assert!(m.decode_only_iters > 0, "workload must include pure-decode iterations");
        assert!(r.contains("pred/meas[decode "), "{r}");
    }

    #[test]
    fn traced_serve_summarizes_and_matches_untraced() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(3, 4, 5, cfg.vocab);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(32)
            .max_batch(3)
            .threads(2)
            .build();
        let plain = c.serve(&reqs, &ServeOptions::continuous(ccfg.clone()));
        assert!(plain.trace.is_none(), "tracing is off by default");
        assert!(!plain.render().contains("trace["));
        let traced = c.serve(&reqs, &ServeOptions::continuous(ccfg).trace());
        assert_eq!(plain.outputs, traced.outputs, "tracing must not change tokens");
        let t = traced.trace.as_ref().expect("traced runs carry a summary");
        assert!(t.events > 0, "a served workload must record events");
        assert_eq!(t.dropped, 0, "default ring capacity must hold a tiny run");
        // 2 worker tracks + the scheduler track.
        assert_eq!(t.workers.len(), 3, "{t:?}");
        assert_eq!(t.workers[2].name, "scheduler");
        assert!(t.phases.iter().any(|p| p.name == "iterate"), "{t:?}");
        assert!(t.phases.iter().any(|p| p.name == "lm_head"), "{t:?}");
        assert!(traced.render().contains(" | trace["), "{}", traced.render());
    }

    #[test]
    fn report_json_has_stable_shape() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(2, 4, 3, cfg.vocab);
        // FCFS: every nullable section reads as literal null.
        let j = c.serve(&reqs, &ServeOptions::fcfs()).to_json();
        assert!(j.starts_with("{\"schema\":\"serve_report.v1\",\"requests\":2,"), "{j}");
        for key in [
            "\"plan\":null",
            "\"tier\":null",
            "\"serving\":null",
            "\"faults\":null",
            "\"spec\":null",
            "\"trace\":null",
        ] {
            assert!(j.contains(key), "{j}");
        }
        // Traced autotuned run: every section is an object.
        let machine = crate::cost::MachineSpec::ryzen_5900x();
        let rep = c.serve(&reqs, &ServeOptions::autotuned(2).machine(machine).trace());
        let j = rep.to_json();
        assert!(j.contains("\"plan\":{\"hash\":\""), "{j}");
        assert!(j.contains("\"predicted_decode_iter_s\":"), "{j}");
        assert!(j.contains("\"serving\":{\"iterations\":"), "{j}");
        assert!(j.contains("\"decode_iter_mean_s\":"), "{j}");
        // Continuous runs always carry the fault ledger (all-zero on a
        // calm run) so downstream parsers see one shape per mode.
        assert!(j.contains("\"faults\":{\"injected\":0"), "{j}");
        // ... but `spec` stays null until the knob is on, mirroring the
        // report field's contract.
        assert!(j.contains("\"spec\":null"), "{j}");
        assert!(j.contains("\"trace\":{\"events\":"), "{j}");
        assert!(!j.contains("NaN") && !j.contains("inf"), "{j}");
        // Braces and quotes balance — the cheap well-formedness check
        // (tools/trace_summary.py and CI run a real JSON parse).
        let depth = j.chars().fold(0i64, |d, c| d + (c == '{') as i64 - (c == '}') as i64);
        assert_eq!(depth, 0, "{j}");
        assert_eq!(j.matches('"').count() % 2, 0, "{j}");
    }

    #[test]
    fn tiered_run_is_recorded_in_report() {
        use crate::serving::TierConfig;
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(3, 4, 5, cfg.vocab);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(32)
            .max_batch(3)
            .threads(1)
            .tiering(TierConfig::new(8))
            .build();
        let rep = c.serve(&reqs, &ServeOptions::continuous(ccfg));
        assert_eq!(rep.generated_tokens, 15);
        assert_eq!(rep.tier.as_deref(), Some("cold=8xint8 swap=always"));
        assert!(rep.render().contains("tier[cold=8xint8 swap=always]"), "{}", rep.render());
        let m = rep.serving.expect("continuous metrics");
        assert!(m.tiered);
        // A roomy pool never spills: the tier is configured but idle.
        assert_eq!(m.swap_preemptions, 0);
    }

    #[test]
    fn chunked_prefill_policy_matches_chunk_one() {
        // Chunked prefill changes only when prompt positions are
        // computed, never their values: outputs are token-identical to
        // the chunk-1 run, in fewer iterations.
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(3, 9, 4, cfg.vocab);
        let run = |c: &mut Coordinator, chunk: usize| {
            let ccfg = ContinuousConfig::builder()
                .block_size(4)
                .num_blocks(64)
                .max_batch(3)
                .prefill_chunk(chunk)
                .build();
            c.serve(&reqs, &ServeOptions::continuous(ccfg))
        };
        let base = run(&mut c, 1);
        let chunked = run(&mut c, 6);
        assert_eq!(base.outputs, chunked.outputs, "chunking must not change tokens");
        let mb = base.serving.as_ref().unwrap();
        let mc = chunked.serving.as_ref().unwrap();
        assert!(
            mc.iterations < mb.iterations,
            "chunked prefill must take fewer iterations: {} vs {}",
            mc.iterations,
            mb.iterations
        );
        assert!(mc.chunk_size.max() >= 6.0, "the 6-token chunk must actually pack");
        assert_eq!(mb.chunk_size.max(), 1.0, "chunk 1 packs single-token spans");
        assert_eq!(
            mc.decode_steps, mb.decode_steps,
            "chunking touches prefill only, never decode"
        );
    }

    #[test]
    fn degenerate_requests_round_trip() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = vec![
            Request { id: 5, prompt: vec![], max_new_tokens: 3 },
            Request { id: 9, prompt: vec![1, 2], max_new_tokens: 0 },
        ];
        for opts in [
            ServeOptions::fcfs(),
            ServeOptions::continuous(ContinuousConfig::default()),
        ] {
            let rep = c.serve(&reqs, &opts);
            assert_eq!(rep.generated_tokens, 0);
            assert_eq!(rep.outputs, vec![(5, vec![]), (9, vec![])]);
        }
    }

    #[test]
    fn serve_options_are_validated_as_a_set() {
        // FCFS takes no overrides — the knobs would silently do nothing.
        assert!(ServeOptions::fcfs().validate().is_ok());
        assert!(ServeOptions::fcfs().threads(2).validate().is_err());
        assert!(ServeOptions::fcfs().shards(2).validate().is_err());
        assert!(ServeOptions::fcfs().trace().validate().is_err());
        assert!(ServeOptions::fcfs().trace_out("t.json").validate().is_err());
        // ... and the robustness knobs are continuous-only too: the
        // oracle must stay the unperturbed reference.
        assert!(ServeOptions::fcfs().deadline_ms(10).validate().is_err());
        assert!(ServeOptions::fcfs().max_queue(4).validate().is_err());
        assert!(ServeOptions::fcfs().faults(FaultPlan::new().fail_fetch(0)).validate().is_err());
        assert!(ServeOptions::fcfs().spec_k(4).validate().is_err());
        // Degenerate values are named, not clamped into surprises.
        let cfg = ContinuousConfig::default();
        assert!(ServeOptions::continuous(cfg.clone()).shards(0).validate().is_err());
        assert!(ServeOptions::continuous(cfg.clone()).threads(0).validate().is_err());
        assert!(ServeOptions::continuous(cfg.clone()).max_queue(0).validate().is_err());
        assert!(ServeOptions::autotuned(0).validate().is_err());
        assert!(ServeOptions::continuous(cfg.clone())
            .deadline_ms(50)
            .max_queue(8)
            .validate()
            .is_ok());
        assert!(ServeOptions::continuous(cfg.clone()).spec_k(4).validate().is_ok());
        assert!(ServeOptions::continuous(cfg).shards(2).threads(2).validate().is_ok());
        // The config builder rejects inconsistent knob sets.
        assert!(ContinuousConfig::builder().block_size(0).try_build().is_err());
        assert!(ContinuousConfig::builder().num_blocks(4).max_batch(8).try_build().is_err());
        assert!(ContinuousConfig::builder()
            .max_batch(4)
            .prefill_chunk(8)
            .step_token_budget(6)
            .try_build()
            .is_err());
        assert!(ContinuousConfig::builder()
            .max_batch(4)
            .prefill_chunk(8)
            .step_token_budget(8)
            .try_build()
            .is_ok());
    }

    #[test]
    fn sharded_serve_records_the_dist_layout_and_matches_unsharded() {
        // The end-to-end sharding contract at the coordinator level:
        // identical tokens, and a report that proves the dist cost
        // model (not a hardcoded layout) picked the per-matrix SBP.
        let cfg = Qwen3Config::tiny();
        let machine = crate::cost::MachineSpec::test_numa();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(3, 6, 5, cfg.vocab);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(32)
            .max_batch(3)
            .threads(2)
            .build();
        let base = c.serve(&reqs, &ServeOptions::continuous(ccfg.clone()));
        let sharded = c.serve(
            &reqs,
            &ServeOptions::continuous(ccfg).shards(2).machine(machine.clone()),
        );
        assert_eq!(base.outputs, sharded.outputs, "sharding must not change tokens");
        assert_eq!(sharded.shards, 2);
        let sig = sharded.sbp_sig.as_deref().expect("sharded runs record their layout");
        let want = crate::dist::ShardSpec::derive(&cfg, &machine, 2).sig();
        assert_eq!(sig, want, "the recorded signature is the dist-extracted one");
        assert!(sig.contains("S(1)"), "dist chose nothing to shard: {sig}");
        assert!(sharded.render().contains("shards=2 sbp["), "{}", sharded.render());
        // shards(1) is an explicit no-op, not an error.
        let one = c.serve(
            &reqs,
            &ServeOptions::autotuned(3).machine(machine).shards(1),
        );
        assert_eq!(one.shards, 1);
        assert_eq!(one.plan.as_ref().unwrap().sbp_sig, "-");
    }

    #[test]
    fn autotuned_sharded_plan_hash_pins_the_sbp_signature() {
        // An autotuned sharded run must fold the dist-chosen layout
        // into the plan hash: same knobs, different shard layout ->
        // different identity.
        let cfg = Qwen3Config::tiny();
        let machine = crate::cost::MachineSpec::test_numa();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(2, 4, 3, cfg.vocab);
        let base = c.serve(&reqs, &ServeOptions::autotuned(2).machine(machine.clone()));
        let sharded =
            c.serve(&reqs, &ServeOptions::autotuned(2).machine(machine).shards(2));
        assert_eq!(base.outputs, sharded.outputs, "plans are pure perf artifacts");
        let (bp, sp) = (base.plan.unwrap(), sharded.plan.unwrap());
        assert_eq!(sp.shards, 2);
        assert!(sp.sbp_sig.contains("wq="), "{}", sp.sbp_sig);
        assert_ne!(bp.plan_hash(), sp.plan_hash(), "layout must be plan identity");
        assert!(sp.render().contains("sbp["), "{}", sp.render());
    }

    #[test]
    fn speculative_serve_matches_plain_and_reports_spec() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(3, 6, 8, cfg.vocab);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(64)
            .max_batch(3)
            .build();
        let plain = c.serve(&reqs, &ServeOptions::continuous(ccfg.clone()));
        assert!(plain.spec.is_none(), "spec-off runs report no spec section");
        assert!(plain.to_json().contains("\"spec\":null"));
        let spec = c.serve(&reqs, &ServeOptions::continuous(ccfg).spec_k(4));
        assert_eq!(plain.outputs, spec.outputs, "speculation must not change tokens");
        let s = spec.spec.as_ref().expect("spec-on runs carry the summary");
        assert_eq!(s.spec_k, 4);
        assert_eq!(s.drafted, s.accepted + s.rejected);
        let j = spec.to_json();
        assert!(j.contains("\"spec\":{\"spec_k\":4"), "{j}");
        assert!(j.contains("\"accepted_tokens_per_step\":"), "{j}");
        // Autotuned: the plan hash pins the speculative depth, like the
        // shard layout — one hash, one executed configuration.
        let machine = crate::cost::MachineSpec::ryzen_5900x();
        let base = c.serve(&reqs, &ServeOptions::autotuned(3).machine(machine.clone()));
        let tuned = c.serve(&reqs, &ServeOptions::autotuned(3).machine(machine).spec_k(4));
        assert_eq!(base.outputs, tuned.outputs, "spec_k is a pure perf knob");
        assert_eq!(tuned.plan.as_ref().unwrap().spec_k, 4);
        assert_ne!(
            base.plan.unwrap().plan_hash(),
            tuned.plan.unwrap().plan_hash(),
            "speculative depth must be plan identity"
        );
    }

    #[test]
    fn injected_panic_recovers_and_matches_the_oracle() {
        // The tentpole contract end to end: a worker panic mid-serve
        // poisons the barrier, the epoch loop audits + requeues, the
        // fresh SPMD scope replays from committed KV — and the outputs
        // are token-identical to the unperturbed FCFS oracle.
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 2, 64));
        let reqs = synthetic_workload(3, 4, 6, cfg.vocab);
        let oracle = c.serve(&reqs, &ServeOptions::fcfs());
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            .num_blocks(32)
            .max_batch(3)
            .build();
        let plan = FaultPlan::parse("panic@phase=attn,iter=3,worker=1")
            .expect("spec must parse");
        let rep = c.serve(
            &reqs,
            &ServeOptions::continuous(ccfg).threads(2).faults(plan),
        );
        assert_eq!(oracle.outputs, rep.outputs, "recovery must not change tokens");
        let f = rep.faults.as_ref().expect("continuous runs carry the fault ledger");
        assert_eq!(f.injected, 1, "the one-shot panic fired exactly once");
        assert_eq!(f.recovered, 1, "one epoch restart absorbed it");
        assert!(f.requeued >= 1, "in-flight work was rolled back and requeued");
        assert!(rep.render().contains("faults injected=1"), "{}", rep.render());
        let m = rep.serving.as_ref().unwrap();
        assert_eq!(m.fault_leaked_blocks, 0, "recovery audit must find no leaks");
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_policy_shim_still_serves() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 1, 64));
        let reqs = synthetic_workload(2, 4, 3, cfg.vocab);
        let a = c.serve_with_policy(&reqs, ServePolicy::Fcfs);
        let b = c.serve_with_policy(
            &reqs,
            ServePolicy::Continuous(ContinuousConfig::default()),
        );
        assert_eq!(a.outputs, b.outputs, "the shim routes through the same engine");
    }
}
