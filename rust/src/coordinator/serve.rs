//! Request serving: FCFS queue over the decode engine with throughput and
//! latency metrics (the workload of the E2E driver).

use std::time::Instant;

use super::Qwen3Engine;
use crate::util::Stats;

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<usize>,
    pub max_new_tokens: usize,
}

/// Aggregate serving metrics.
#[derive(Debug)]
pub struct ServeReport {
    pub requests: usize,
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub wall_s: f64,
    /// Decode throughput over generated tokens only.
    pub decode_tokens_per_s: f64,
    /// Per-token decode latency stats (seconds).
    pub token_latency: Stats,
    /// Per-request end-to-end latency stats (seconds).
    pub request_latency: Stats,
    /// Generated token ids per request.
    pub outputs: Vec<(u64, Vec<usize>)>,
}

impl ServeReport {
    pub fn render(&self) -> String {
        format!(
            "requests={} prompt_toks={} gen_toks={} wall={:.2}s decode={:.2} tok/s \
             tok_lat p50={:.2}ms p99={:.2}ms req_lat mean={:.2}s",
            self.requests,
            self.prompt_tokens,
            self.generated_tokens,
            self.wall_s,
            self.decode_tokens_per_s,
            self.token_latency.percentile(50.0) * 1e3,
            self.token_latency.percentile(99.0) * 1e3,
            self.request_latency.mean(),
        )
    }
}

/// The FCFS serving coordinator (batch size 1, matching §4's methodology).
pub struct Coordinator {
    pub engine: Qwen3Engine,
}

impl Coordinator {
    pub fn new(engine: Qwen3Engine) -> Self {
        Coordinator { engine }
    }

    /// Serve a list of requests to completion.
    pub fn serve(&mut self, requests: &[Request]) -> ServeReport {
        let wall = Instant::now();
        let mut token_latency = Stats::default();
        let mut request_latency = Stats::default();
        let mut outputs = Vec::new();
        let mut prompt_tokens = 0usize;
        let mut generated = 0usize;
        for req in requests {
            let t_req = Instant::now();
            self.engine.reset();
            let mut pos = 0usize;
            let mut logits = Vec::new();
            for &tok in &req.prompt {
                logits = self.engine.decode_step(tok, pos);
                pos += 1;
            }
            prompt_tokens += req.prompt.len();
            let mut toks = Vec::with_capacity(req.max_new_tokens);
            let mut next = super::engine::argmax(&logits);
            for _ in 0..req.max_new_tokens {
                let t_tok = Instant::now();
                toks.push(next);
                logits = self.engine.decode_step(next, pos);
                pos += 1;
                next = super::engine::argmax(&logits);
                token_latency.push(t_tok.elapsed().as_secs_f64());
                generated += 1;
            }
            request_latency.push(t_req.elapsed().as_secs_f64());
            outputs.push((req.id, toks));
        }
        let wall_s = wall.elapsed().as_secs_f64();
        let decode_s: f64 = token_latency.mean() * generated as f64;
        ServeReport {
            requests: requests.len(),
            prompt_tokens,
            generated_tokens: generated,
            wall_s,
            decode_tokens_per_s: if decode_s > 0.0 { generated as f64 / decode_s } else { 0.0 },
            token_latency,
            request_latency,
            outputs,
        }
    }
}

/// Build a deterministic synthetic workload (`n` requests with pseudo-
/// random prompts over the model vocab).
pub fn synthetic_workload(n: usize, prompt_len: usize, max_new: usize, vocab: usize) -> Vec<Request> {
    let mut rng = crate::util::Rng::new(0xBEEF);
    (0..n)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..prompt_len).map(|_| rng.below(vocab)).collect(),
            max_new_tokens: max_new,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Qwen3Config, Qwen3Weights};

    #[test]
    fn serves_and_reports() {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 7);
        let mut c = Coordinator::new(Qwen3Engine::new(w, 2, 64));
        let reqs = synthetic_workload(3, 4, 5, cfg.vocab);
        let rep = c.serve(&reqs);
        assert_eq!(rep.requests, 3);
        assert_eq!(rep.generated_tokens, 15);
        assert_eq!(rep.prompt_tokens, 12);
        assert!(rep.decode_tokens_per_s > 0.0);
        assert_eq!(rep.outputs.len(), 3);
        assert!(rep.render().contains("tok/s"));
    }

    #[test]
    fn workload_deterministic() {
        let a = synthetic_workload(2, 3, 4, 100);
        let b = synthetic_workload(2, 3, 4, 100);
        assert_eq!(a[0].prompt, b[0].prompt);
        assert_eq!(a[1].prompt, b[1].prompt);
        assert_ne!(a[0].prompt, a[1].prompt);
    }
}
