//! The decode engine: real Qwen3 inference over NTT μkernels with
//! compile-time static partitioning across cores.
//!
//! The SPMD building blocks (sense-reversing barrier, deterministic
//! `splits`, disjoint-range scratch, single-writer KV handoff) live in
//! [`crate::parallel`] and are shared with the batched paged-attention
//! engine of [`crate::serving::batch_engine`].

use crate::model::{Qwen3Config, Qwen3Weights};
use crate::ntt::{
    add_inplace, dot, gemv_cols, mul_inplace, rmsnorm, rope_inplace, silu_inplace,
    softmax_inplace, Tensor,
};
use crate::parallel::{splits, KvCell, PoisonGuard, SharedVec, SpinBarrier};

/// Per-layer KV cache: rows are positions, columns `kv_heads * head_dim`.
pub struct KvCache {
    pub k: Tensor,
    pub v: Tensor,
    pub len: usize,
}

impl KvCache {
    fn new(max_seq: usize, width: usize) -> Self {
        KvCache { k: Tensor::zeros(&[max_seq, width]), v: Tensor::zeros(&[max_seq, width]), len: 0 }
    }
}

/// The decode engine.
pub struct Qwen3Engine {
    /// Pristine model weights (the batched engine quantizes its packed
    /// plane from these when `cfg.weight_quant` is quantized).
    pub weights: Qwen3Weights,
    /// Fake-quantized twin used by [`Qwen3Engine::decode_step`] when
    /// `cfg.weight_quant` is quantized: the GEMM matrices round-tripped
    /// through their `QuantMat`, i.e. the exact f32 values the fused
    /// dequant-GEMM kernels FMA. The dense engine has no fused kernels
    /// of its own, but running on these keeps it the *bit-exact*
    /// differential oracle for the quantized batched path. Built
    /// lazily on the first dense decode step — a continuous-only serve
    /// never reads it, and eagerly holding a second full f32 copy of
    /// the model would double the resident weights for nothing. Always
    /// `None` on the F32 path (zero cost, bitwise the seed behaviour).
    fq: Option<Qwen3Weights>,
    pub kv: Vec<KvCache>,
    pub threads: usize,
    max_seq: usize,
}

impl Qwen3Engine {
    /// `threads` is clamped to `[1, cfg.partition_width()]`: the static
    /// column/head partition shards every dimension down to `kv_heads`
    /// wide, so worker counts beyond the model's partitionable width
    /// would only produce empty shards (wasted threads spinning on every
    /// barrier).
    pub fn new(weights: Qwen3Weights, threads: usize, max_seq: usize) -> Self {
        let cfg = weights.cfg.clone();
        let width = cfg.kv_heads * cfg.head_dim;
        let kv = (0..cfg.layers).map(|_| KvCache::new(max_seq, width)).collect();
        let threads = threads.clamp(1, cfg.partition_width());
        Qwen3Engine { weights, fq: None, kv, threads, max_seq }
    }

    pub fn cfg(&self) -> &Qwen3Config {
        &self.weights.cfg
    }

    pub fn reset(&mut self) {
        for c in &mut self.kv {
            c.len = 0;
        }
    }

    /// One decode step: consume `token` at position `pos`, return logits.
    ///
    /// §Perf L3: the whole step runs in **one** parallel region (one
    /// `thread::scope` per step instead of per-phase fork-join), with the
    /// compile-time static partition expressed as barrier-separated SPMD
    /// phases — the "static task partitioning and core mapping" of §4.2.
    /// This removed the per-phase spawn overhead that made multi-thread
    /// decode slower than 1T on small models (see EXPERIMENTS.md §Perf).
    pub fn decode_step(&mut self, token: usize, pos: usize) -> Vec<f32> {
        assert!(pos < self.max_seq, "KV cache overflow");
        // Lazily materialize the fake-quantized twin on the first dense
        // step under a quantized weight plane (see the field doc).
        if self.weights.cfg.weight_quant.is_quantized() && self.fq.is_none() {
            self.fq = Some(self.weights.fake_quantized(self.weights.cfg.weight_quant));
        }
        let cfg = self.weights.cfg.clone();
        let h = cfg.hidden;
        let hd = cfg.head_dim;
        let heads = cfg.heads;
        let kvh = cfg.kv_heads;
        let qdim = heads * hd;
        let kvdim = kvh * hd;
        let inter = cfg.intermediate;
        let t = self.threads;
        let seq = pos + 1;

        // Residual stream + scratch, shared across the SPMD workers.
        let x = SharedVec::new(h);
        x.write_all(self.weights.embedding.row(token % cfg.vocab));
        let xn = SharedVec::new(h);
        let q = SharedVec::new(qdim);
        let kvec = SharedVec::new(kvdim);
        let vvec = SharedVec::new(kvdim);
        let ctx = SharedVec::new(qdim);
        let attn_out = SharedVec::new(h);
        let gate = SharedVec::new(inter);
        let up = SharedVec::new(inter);
        let down = SharedVec::new(h);
        let logits = SharedVec::new(cfg.vocab);
        // KV caches are committed by worker 0 in a barrier-separated
        // phase; the cell hands out the &mut only there (see KvCell docs
        // for the checked invariant).
        let kv_cell = KvCell::new(&mut self.kv);

        // Compute over the fake-quantized twin when the config asks for
        // a quantized weight plane (field borrows stay disjoint from
        // the `&mut self.kv` held by `kv_cell` above).
        let weights = self.fq.as_ref().unwrap_or(&self.weights);
        let barrier = SpinBarrier::new(t);
        std::thread::scope(|s| {
            for wi in 0..t {
                let (x, xn, q, kvec, vvec, ctx, attn_out, gate, up, down, logits) = (
                    &x, &xn, &q, &kvec, &vvec, &ctx, &attn_out, &gate, &up, &down, &logits,
                );
                let (barrier, kv_cell) = (&barrier, &kv_cell);
                s.spawn(move || {
                    // A panicking worker poisons the barrier so its
                    // siblings unwind instead of spinning forever on a
                    // participant that will never arrive (see SpinBarrier).
                    let _poison = PoisonGuard::new(barrier);
                    for l in 0..cfg.layers {
                        let w = &weights.layers[l];
                        // Phase 0 (serial): attention RMSNorm.
                        if wi == 0 {
                            unsafe {
                                rmsnorm(
                                    x.read(),
                                    &w.attn_norm.data,
                                    cfg.rms_eps,
                                    xn.slice_mut(0, h),
                                );
                            }
                        }
                        barrier.wait();
                        // Phase 1: QKV projections, column-split S(1).
                        let (qlo, qhi) = splits(qdim, t)[wi];
                        let (klo, khi) = splits(kvdim, t)[wi];
                        unsafe {
                            gemv_cols(xn.read(), &w.wq, qlo, qhi, q.slice_mut(qlo, qhi));
                            gemv_cols(xn.read(), &w.wk, klo, khi, kvec.slice_mut(klo, khi));
                            gemv_cols(xn.read(), &w.wv, klo, khi, vvec.slice_mut(klo, khi));
                        }
                        barrier.wait();
                        // Phase 2: RoPE, heads split across workers.
                        let (h0, h1) = splits(heads, t)[wi];
                        for head in h0..h1 {
                            unsafe {
                                rope_inplace(
                                    q.slice_mut(head * hd, (head + 1) * hd),
                                    pos,
                                    cfg.rope_theta,
                                );
                            }
                        }
                        let (k0, k1) = splits(kvh, t)[wi];
                        for head in k0..k1 {
                            unsafe {
                                rope_inplace(
                                    kvec.slice_mut(head * hd, (head + 1) * hd),
                                    pos,
                                    cfg.rope_theta,
                                );
                            }
                        }
                        barrier.wait();
                        // Phase 3 (serial): commit this position's K/V.
                        if wi == 0 {
                            kv_cell.commit(wi, |kv| {
                                kv[l].k.row_mut(pos).copy_from_slice(kvec.read());
                                kv[l].v.row_mut(pos).copy_from_slice(vvec.read());
                                kv[l].len = seq;
                            });
                        }
                        barrier.wait();
                        // Phase 4: attention per query head (GQA).
                        let kv = kv_cell.read();
                        let kc = &kv[l];
                        let group = heads / kvh;
                        let inv_sqrt = 1.0 / (hd as f32).sqrt();
                        for head in h0..h1 {
                            let kvhead = head / group;
                            let qrow = &q.read()[head * hd..(head + 1) * hd];
                            let mut scores = vec![0.0f32; seq];
                            for (p, score) in scores.iter_mut().enumerate() {
                                let krow = &kc.k.row(p)[kvhead * hd..(kvhead + 1) * hd];
                                *score = dot(qrow, krow) * inv_sqrt;
                            }
                            softmax_inplace(&mut scores);
                            let out = unsafe { ctx.slice_mut(head * hd, (head + 1) * hd) };
                            out.fill(0.0);
                            for (p, &sc) in scores.iter().enumerate() {
                                let vrow = &kc.v.row(p)[kvhead * hd..(kvhead + 1) * hd];
                                for (o, &vv) in out.iter_mut().zip(vrow) {
                                    *o += sc * vv;
                                }
                            }
                        }
                        barrier.wait();
                        // Phase 5: output projection, column-split.
                        let (olo, ohi) = splits(h, t)[wi];
                        unsafe {
                            gemv_cols(ctx.read(), &w.wo, olo, ohi, attn_out.slice_mut(olo, ohi));
                        }
                        barrier.wait();
                        // Phase 6 (serial): residual + MLP RMSNorm.
                        if wi == 0 {
                            unsafe {
                                add_inplace(x.slice_mut(0, h), attn_out.read());
                                rmsnorm(
                                    x.read(),
                                    &w.mlp_norm.data,
                                    cfg.rms_eps,
                                    xn.slice_mut(0, h),
                                );
                            }
                        }
                        barrier.wait();
                        // Phase 7: SwiGLU gate/up, column-split.
                        let (ilo, ihi) = splits(inter, t)[wi];
                        unsafe {
                            gemv_cols(xn.read(), &w.w_gate, ilo, ihi, gate.slice_mut(ilo, ihi));
                            gemv_cols(xn.read(), &w.w_up, ilo, ihi, up.slice_mut(ilo, ihi));
                            let gseg = gate.slice_mut(ilo, ihi);
                            silu_inplace(gseg);
                            mul_inplace(gseg, &up.read()[ilo..ihi]);
                        }
                        barrier.wait();
                        // Phase 8: down projection, column-split.
                        let (dlo, dhi) = splits(h, t)[wi];
                        unsafe {
                            gemv_cols(gate.read(), &w.w_down, dlo, dhi, down.slice_mut(dlo, dhi));
                        }
                        barrier.wait();
                        // Phase 9 (serial): residual.
                        if wi == 0 {
                            unsafe {
                                add_inplace(x.slice_mut(0, h), down.read());
                            }
                        }
                        barrier.wait();
                    }
                    // Final norm (serial) + LM head (column split).
                    if wi == 0 {
                        unsafe {
                            rmsnorm(
                                x.read(),
                                &weights.final_norm.data,
                                cfg.rms_eps,
                                xn.slice_mut(0, h),
                            );
                        }
                    }
                    barrier.wait();
                    let (lo, hi) = splits(cfg.vocab, t)[wi];
                    unsafe {
                        gemv_cols(xn.read(), &weights.lm_head, lo, hi, logits.slice_mut(lo, hi));
                    }
                });
            }
        });
        logits.read().to_vec()
    }

    /// Greedy-decode `n_new` tokens after feeding `prompt`.
    pub fn generate(&mut self, prompt: &[usize], n_new: usize) -> Vec<usize> {
        self.reset();
        let mut pos = 0usize;
        let mut logits = Vec::new();
        for &tok in prompt {
            logits = self.decode_step(tok, pos);
            pos += 1;
        }
        let mut out = Vec::with_capacity(n_new);
        let mut next = argmax(&logits);
        for _ in 0..n_new {
            out.push(next);
            logits = self.decode_step(next, pos);
            pos += 1;
            next = argmax(&logits);
        }
        out
    }
}

/// Index of the maximum logit.
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Qwen3Config;

    fn tiny_engine(threads: usize) -> Qwen3Engine {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 1234);
        Qwen3Engine::new(w, threads, 64)
    }

    #[test]
    fn logits_shape_and_finite() {
        let mut e = tiny_engine(1);
        let logits = e.decode_step(7, 0);
        assert_eq!(logits.len(), e.cfg().vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn multithread_matches_singlethread() {
        // The static partition must be numerically identical (same
        // reduction order within each shard).
        let mut e1 = tiny_engine(1);
        let mut e4 = tiny_engine(4);
        let prompt = [3usize, 141, 59, 26];
        for (i, &tok) in prompt.iter().enumerate() {
            let l1 = e1.decode_step(tok, i);
            let l4 = e4.decode_step(tok, i);
            let maxdiff = l1
                .iter()
                .zip(&l4)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(maxdiff < 1e-4, "thread-count changed numerics: {maxdiff}");
        }
    }

    #[test]
    fn oversubscribed_threads_clamp_to_partition_width() {
        // Tiny has kv_heads = 2: the narrowest split dimension. A 64-way
        // request must clamp there instead of spawning workers with
        // empty shards.
        let e = tiny_engine(64);
        assert_eq!(e.threads, e.cfg().partition_width());
        assert_eq!(e.threads, 2);
        // And the lower clamp still holds.
        assert_eq!(tiny_engine(0).threads, 1);
    }

    #[test]
    fn kv_cache_grows_and_changes_output() {
        let mut e = tiny_engine(2);
        let l0 = e.decode_step(5, 0);
        let l1 = e.decode_step(5, 1);
        assert_eq!(e.kv[0].len, 2);
        // Same token at a later position attends to history: different
        // logits.
        let diff = l0.iter().zip(&l1).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(diff > 1e-7);
    }

    #[test]
    fn generate_is_deterministic() {
        let mut e1 = tiny_engine(2);
        let mut e2 = tiny_engine(2);
        let a = e1.generate(&[1, 2, 3], 8);
        let b = e2.generate(&[1, 2, 3], 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|&t| t < e1.cfg().vocab));
    }

    #[test]
    fn reset_clears_state() {
        let mut e = tiny_engine(1);
        let a = e.generate(&[9, 8], 4);
        let b = e.generate(&[9, 8], 4);
        assert_eq!(a, b, "reset must restore identical state");
    }
}
