//! The decode engine: real Qwen3 inference over NTT μkernels with
//! compile-time static partitioning across cores.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Sense-reversing spin barrier: ~100 ns per wait vs several us for the
/// mutex/condvar `std::sync::Barrier` (§Perf L3 — the decode step passes
/// ~40 barriers per token, so this matters on small models).
struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    fn new(n: usize) -> Self {
        SpinBarrier { n, count: AtomicUsize::new(0), generation: AtomicUsize::new(0) }
    }

    fn wait(&self) {
        if self.n <= 1 {
            return;
        }
        let gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            self.count.store(0, Ordering::Relaxed);
            self.generation.store(gen.wrapping_add(1), Ordering::Release);
        } else {
            // Spin briefly, then yield: on oversubscribed machines (or a
            // 1-CPU container) pure spinning burns whole scheduler quanta
            // while the straggler cannot run.
            let mut spins = 0u32;
            while self.generation.load(Ordering::Acquire) == gen {
                spins += 1;
                if spins < 512 {
                    std::hint::spin_loop();
                } else {
                    std::thread::yield_now();
                }
            }
        }
    }
}

use crate::model::{Qwen3Config, Qwen3Weights};
use crate::ntt::{
    add_inplace, dot, gemv_cols, mul_inplace, rmsnorm, rope_inplace, silu_inplace,
    softmax_inplace, Tensor,
};

/// Per-layer KV cache: rows are positions, columns `kv_heads * head_dim`.
pub struct KvCache {
    pub k: Tensor,
    pub v: Tensor,
    pub len: usize,
}

impl KvCache {
    fn new(max_seq: usize, width: usize) -> Self {
        KvCache { k: Tensor::zeros(&[max_seq, width]), v: Tensor::zeros(&[max_seq, width]), len: 0 }
    }
}

/// Column ranges statically assigned to each worker (the S(1) split the
/// Auto Distribution pass selects for 1-row GEMV).
fn splits(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for p in 0..parts {
        let sz = base + usize::from(p < rem);
        out.push((lo, lo + sz));
        lo += sz;
    }
    out
}

/// Shared mutable scratch written by disjoint ranges from worker threads.
struct SharedVec(std::cell::UnsafeCell<Vec<f32>>);
unsafe impl Sync for SharedVec {}

/// Single-writer handoff cell for the KV-cache commit.
///
/// Invariant (checked with `debug_assert!`s): only worker 0 calls
/// [`KvCell::commit`], and every `commit` is separated from every
/// [`KvCell::read`] by a barrier — writes in phase 3 happen-before reads
/// in phase 4 via the barrier's Release/Acquire pair. The `writers`
/// counter turns a violated invariant into a deterministic debug panic
/// instead of a silent data race; block tables in the paged serving path
/// make these aliasing rules stricter, so the contract is enforced here
/// rather than scattered across raw `UnsafeCell` pokes.
struct KvCell<'a> {
    kv: std::cell::UnsafeCell<&'a mut Vec<KvCache>>,
    writers: AtomicUsize,
}

unsafe impl Sync for KvCell<'_> {}

impl<'a> KvCell<'a> {
    fn new(kv: &'a mut Vec<KvCache>) -> Self {
        KvCell { kv: std::cell::UnsafeCell::new(kv), writers: AtomicUsize::new(0) }
    }

    /// Exclusive commit window. SAFETY: caller must be the single writer
    /// (worker 0) inside a barrier-separated phase.
    fn commit(&self, worker: usize, f: impl FnOnce(&mut Vec<KvCache>)) {
        debug_assert_eq!(worker, 0, "only worker 0 may commit the KV cache");
        let prev = self.writers.fetch_add(1, Ordering::AcqRel);
        debug_assert_eq!(prev, 0, "concurrent KV commit: barrier invariant violated");
        let _ = prev;
        // SAFETY: single writer by contract (debug-checked above); all
        // readers are on the other side of a barrier.
        f(unsafe { &mut **self.kv.get() });
        self.writers.fetch_sub(1, Ordering::AcqRel);
    }

    /// Shared read. SAFETY: must be barrier-separated from any commit.
    fn read(&self) -> &Vec<KvCache> {
        debug_assert_eq!(
            self.writers.load(Ordering::Acquire),
            0,
            "KV read overlapping a commit: barrier invariant violated"
        );
        // SAFETY: no writer is active (debug-checked above); the commit
        // phase happened-before this read via the barrier.
        unsafe { &**self.kv.get() }
    }
}

impl SharedVec {
    fn new(n: usize) -> Self {
        SharedVec(std::cell::UnsafeCell::new(vec![0.0; n]))
    }

    /// SAFETY: callers must write disjoint ranges between barriers.
    #[allow(clippy::mut_from_ref)]
    unsafe fn slice_mut(&self, lo: usize, hi: usize) -> &mut [f32] {
        let v: &mut Vec<f32> = unsafe { &mut *self.0.get() };
        &mut v[lo..hi]
    }

    fn read(&self) -> &[f32] {
        unsafe { &*self.0.get() }
    }

    fn write_all(&self, src: &[f32]) {
        unsafe { (*self.0.get()).copy_from_slice(src) }
    }
}

/// The decode engine.
pub struct Qwen3Engine {
    pub weights: Qwen3Weights,
    pub kv: Vec<KvCache>,
    pub threads: usize,
    max_seq: usize,
}

impl Qwen3Engine {
    pub fn new(weights: Qwen3Weights, threads: usize, max_seq: usize) -> Self {
        let cfg = weights.cfg.clone();
        let width = cfg.kv_heads * cfg.head_dim;
        let kv = (0..cfg.layers).map(|_| KvCache::new(max_seq, width)).collect();
        Qwen3Engine { weights, kv, threads: threads.max(1), max_seq }
    }

    pub fn cfg(&self) -> &Qwen3Config {
        &self.weights.cfg
    }

    pub fn reset(&mut self) {
        for c in &mut self.kv {
            c.len = 0;
        }
    }

    /// One decode step: consume `token` at position `pos`, return logits.
    ///
    /// §Perf L3: the whole step runs in **one** parallel region (one
    /// `thread::scope` per step instead of per-phase fork-join), with the
    /// compile-time static partition expressed as barrier-separated SPMD
    /// phases — the "static task partitioning and core mapping" of §4.2.
    /// This removed the per-phase spawn overhead that made multi-thread
    /// decode slower than 1T on small models (see EXPERIMENTS.md §Perf).
    pub fn decode_step(&mut self, token: usize, pos: usize) -> Vec<f32> {
        assert!(pos < self.max_seq, "KV cache overflow");
        let cfg = self.weights.cfg.clone();
        let h = cfg.hidden;
        let hd = cfg.head_dim;
        let heads = cfg.heads;
        let kvh = cfg.kv_heads;
        let qdim = heads * hd;
        let kvdim = kvh * hd;
        let inter = cfg.intermediate;
        let t = self.threads;
        let seq = pos + 1;

        // Residual stream + scratch, shared across the SPMD workers.
        let x = SharedVec::new(h);
        x.write_all(self.weights.embedding.row(token % cfg.vocab));
        let xn = SharedVec::new(h);
        let q = SharedVec::new(qdim);
        let kvec = SharedVec::new(kvdim);
        let vvec = SharedVec::new(kvdim);
        let ctx = SharedVec::new(qdim);
        let attn_out = SharedVec::new(h);
        let gate = SharedVec::new(inter);
        let up = SharedVec::new(inter);
        let down = SharedVec::new(h);
        let logits = SharedVec::new(cfg.vocab);
        // KV caches are committed by worker 0 in a barrier-separated
        // phase; the cell hands out the &mut only there (see KvCell docs
        // for the checked invariant).
        let kv_cell = KvCell::new(&mut self.kv);

        let weights = &self.weights;
        let barrier = SpinBarrier::new(t);
        std::thread::scope(|s| {
            for wi in 0..t {
                let (x, xn, q, kvec, vvec, ctx, attn_out, gate, up, down, logits) = (
                    &x, &xn, &q, &kvec, &vvec, &ctx, &attn_out, &gate, &up, &down, &logits,
                );
                let (barrier, kv_cell) = (&barrier, &kv_cell);
                s.spawn(move || {
                    for l in 0..cfg.layers {
                        let w = &weights.layers[l];
                        // Phase 0 (serial): attention RMSNorm.
                        if wi == 0 {
                            unsafe {
                                rmsnorm(x.read(), &w.attn_norm.data, cfg.rms_eps, xn.slice_mut(0, h));
                            }
                        }
                        barrier.wait();
                        // Phase 1: QKV projections, column-split S(1).
                        let (qlo, qhi) = splits(qdim, t)[wi];
                        let (klo, khi) = splits(kvdim, t)[wi];
                        unsafe {
                            gemv_cols(xn.read(), &w.wq, qlo, qhi, q.slice_mut(qlo, qhi));
                            gemv_cols(xn.read(), &w.wk, klo, khi, kvec.slice_mut(klo, khi));
                            gemv_cols(xn.read(), &w.wv, klo, khi, vvec.slice_mut(klo, khi));
                        }
                        barrier.wait();
                        // Phase 2: RoPE, heads split across workers.
                        let (h0, h1) = splits(heads, t)[wi];
                        for head in h0..h1 {
                            unsafe {
                                rope_inplace(
                                    q.slice_mut(head * hd, (head + 1) * hd),
                                    pos,
                                    cfg.rope_theta,
                                );
                            }
                        }
                        let (k0, k1) = splits(kvh, t)[wi];
                        for head in k0..k1 {
                            unsafe {
                                rope_inplace(
                                    kvec.slice_mut(head * hd, (head + 1) * hd),
                                    pos,
                                    cfg.rope_theta,
                                );
                            }
                        }
                        barrier.wait();
                        // Phase 3 (serial): commit this position's K/V.
                        if wi == 0 {
                            kv_cell.commit(wi, |kv| {
                                kv[l].k.row_mut(pos).copy_from_slice(kvec.read());
                                kv[l].v.row_mut(pos).copy_from_slice(vvec.read());
                                kv[l].len = seq;
                            });
                        }
                        barrier.wait();
                        // Phase 4: attention per query head (GQA).
                        let kv = kv_cell.read();
                        let kc = &kv[l];
                        let group = heads / kvh;
                        let inv_sqrt = 1.0 / (hd as f32).sqrt();
                        for head in h0..h1 {
                            let kvhead = head / group;
                            let qrow = &q.read()[head * hd..(head + 1) * hd];
                            let mut scores = vec![0.0f32; seq];
                            for (p, score) in scores.iter_mut().enumerate() {
                                let krow = &kc.k.row(p)[kvhead * hd..(kvhead + 1) * hd];
                                *score = dot(qrow, krow) * inv_sqrt;
                            }
                            softmax_inplace(&mut scores);
                            let out = unsafe { ctx.slice_mut(head * hd, (head + 1) * hd) };
                            out.fill(0.0);
                            for (p, &sc) in scores.iter().enumerate() {
                                let vrow = &kc.v.row(p)[kvhead * hd..(kvhead + 1) * hd];
                                for (o, &vv) in out.iter_mut().zip(vrow) {
                                    *o += sc * vv;
                                }
                            }
                        }
                        barrier.wait();
                        // Phase 5: output projection, column-split.
                        let (olo, ohi) = splits(h, t)[wi];
                        unsafe {
                            gemv_cols(ctx.read(), &w.wo, olo, ohi, attn_out.slice_mut(olo, ohi));
                        }
                        barrier.wait();
                        // Phase 6 (serial): residual + MLP RMSNorm.
                        if wi == 0 {
                            unsafe {
                                add_inplace(x.slice_mut(0, h), attn_out.read());
                                rmsnorm(x.read(), &w.mlp_norm.data, cfg.rms_eps, xn.slice_mut(0, h));
                            }
                        }
                        barrier.wait();
                        // Phase 7: SwiGLU gate/up, column-split.
                        let (ilo, ihi) = splits(inter, t)[wi];
                        unsafe {
                            gemv_cols(xn.read(), &w.w_gate, ilo, ihi, gate.slice_mut(ilo, ihi));
                            gemv_cols(xn.read(), &w.w_up, ilo, ihi, up.slice_mut(ilo, ihi));
                            let gseg = gate.slice_mut(ilo, ihi);
                            silu_inplace(gseg);
                            mul_inplace(gseg, &up.read()[ilo..ihi]);
                        }
                        barrier.wait();
                        // Phase 8: down projection, column-split.
                        let (dlo, dhi) = splits(h, t)[wi];
                        unsafe {
                            gemv_cols(gate.read(), &w.w_down, dlo, dhi, down.slice_mut(dlo, dhi));
                        }
                        barrier.wait();
                        // Phase 9 (serial): residual.
                        if wi == 0 {
                            unsafe {
                                add_inplace(x.slice_mut(0, h), down.read());
                            }
                        }
                        barrier.wait();
                    }
                    // Final norm (serial) + LM head (column split).
                    if wi == 0 {
                        unsafe {
                            rmsnorm(
                                x.read(),
                                &weights.final_norm.data,
                                cfg.rms_eps,
                                xn.slice_mut(0, h),
                            );
                        }
                    }
                    barrier.wait();
                    let (lo, hi) = splits(cfg.vocab, t)[wi];
                    unsafe {
                        gemv_cols(xn.read(), &weights.lm_head, lo, hi, logits.slice_mut(lo, hi));
                    }
                });
            }
        });
        logits.read().to_vec()
    }

    /// Greedy-decode `n_new` tokens after feeding `prompt`.
    pub fn generate(&mut self, prompt: &[usize], n_new: usize) -> Vec<usize> {
        self.reset();
        let mut pos = 0usize;
        let mut logits = Vec::new();
        for &tok in prompt {
            logits = self.decode_step(tok, pos);
            pos += 1;
        }
        let mut out = Vec::with_capacity(n_new);
        let mut next = argmax(&logits);
        for _ in 0..n_new {
            out.push(next);
            logits = self.decode_step(next, pos);
            pos += 1;
            next = argmax(&logits);
        }
        out
    }
}

/// Index of the maximum logit.
pub fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Qwen3Config;

    fn tiny_engine(threads: usize) -> Qwen3Engine {
        let cfg = Qwen3Config::tiny();
        let w = Qwen3Weights::random(&cfg, 1234);
        Qwen3Engine::new(w, threads, 64)
    }

    #[test]
    fn logits_shape_and_finite() {
        let mut e = tiny_engine(1);
        let logits = e.decode_step(7, 0);
        assert_eq!(logits.len(), e.cfg().vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn multithread_matches_singlethread() {
        // The static partition must be numerically identical (same
        // reduction order within each shard).
        let mut e1 = tiny_engine(1);
        let mut e4 = tiny_engine(4);
        let prompt = [3usize, 141, 59, 26];
        for (i, &tok) in prompt.iter().enumerate() {
            let l1 = e1.decode_step(tok, i);
            let l4 = e4.decode_step(tok, i);
            let maxdiff = l1
                .iter()
                .zip(&l4)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(maxdiff < 1e-4, "thread-count changed numerics: {maxdiff}");
        }
    }

    #[test]
    fn kv_cache_grows_and_changes_output() {
        let mut e = tiny_engine(2);
        let l0 = e.decode_step(5, 0);
        let l1 = e.decode_step(5, 1);
        assert_eq!(e.kv[0].len, 2);
        // Same token at a later position attends to history: different
        // logits.
        let diff = l0.iter().zip(&l1).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(diff > 1e-7);
    }

    #[test]
    fn generate_is_deterministic() {
        let mut e1 = tiny_engine(2);
        let mut e2 = tiny_engine(2);
        let a = e1.generate(&[1, 2, 3], 8);
        let b = e2.generate(&[1, 2, 3], 8);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|&t| t < e1.cfg().vocab));
    }

    #[test]
    fn reset_clears_state() {
        let mut e = tiny_engine(1);
        let a = e.generate(&[9, 8], 4);
        let b = e.generate(&[9, 8], 4);
        assert_eq!(a, b, "reset must restore identical state");
    }
}
