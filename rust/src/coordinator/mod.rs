//! The serving coordinator (L3 runtime side).
//!
//! * [`engine`] — the decode engine: KV cache + one decode step executed
//!   with NTT μkernels. Multi-core execution follows the paper's
//!   "multi-core as multi-node" design (§4.2): every heavy operator is
//!   *statically column/head-partitioned* across worker threads at plan
//!   time (the Auto Distribution S(1) strategy for column-parallel
//!   GEMV), synchronized with lightweight barriers — no fork-join work
//!   stealing, no dynamic scheduling.
//! * [`serve`] — the request loop behind [`ServeOptions`]: the FCFS
//!   oracle (batch 1, dense KV) and the continuous-batching path over
//!   the paged KV pool of [`crate::serving`], with token throughput and
//!   latency metrics (the E2E driver of examples/qwen3_serve.rs).

pub mod engine;
pub mod serve;

pub use engine::{argmax, KvCache, Qwen3Engine};
pub use serve::{synthetic_workload, Coordinator, Request, ServeOptions, ServePolicy, ServeReport};
