//! E-graph data structure: union-find, hash-consing, congruence closure.

use std::collections::HashMap;

use crate::ir::{infer_type, Graph, NodeId, Op, TensorType};

/// Id of an e-class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ClassId(pub u32);

impl ClassId {
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

/// An e-node: an operation whose children are e-classes.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ENode {
    pub op: Op,
    pub children: Vec<ClassId>,
}

impl ENode {
    pub fn leaf(op: Op) -> Self {
        ENode { op, children: vec![] }
    }
}

/// An e-class: a set of equivalent e-nodes sharing a [`TensorType`].
///
/// Equivalence is *semantic equality of the value including its layout
/// and distribution attributes* — a packed tensor is a different value
/// from its flat form (they are bridged by explicit Pack/Unpack nodes),
/// and in the distributed e-graph "nodes with consistent SBP attributes
/// are equivalent" (§3.1.3) because the SBP is part of the type.
#[derive(Debug, Clone)]
pub struct EClass {
    pub nodes: Vec<ENode>,
    pub ty: TensorType,
    /// Parent e-nodes (and the class they live in) for congruence repair.
    pub(crate) parents: Vec<(ENode, ClassId)>,
}

/// The e-graph.
#[derive(Debug, Clone, Default)]
pub struct EGraph {
    uf: Vec<u32>,
    classes: HashMap<ClassId, EClass>,
    memo: HashMap<ENode, ClassId>,
    dirty: Vec<ClassId>,
    /// Total number of e-nodes ever added (growth metric for saturation).
    pub n_nodes: usize,
}

impl EGraph {
    pub fn new() -> Self {
        EGraph::default()
    }

    /// Canonical representative of `id`.
    pub fn find(&self, mut id: ClassId) -> ClassId {
        while self.uf[id.index()] != id.0 {
            id = ClassId(self.uf[id.index()]);
        }
        id
    }

    fn find_compress(&mut self, id: ClassId) -> ClassId {
        let root = self.find(id);
        let mut cur = id;
        while self.uf[cur.index()] != root.0 {
            let next = ClassId(self.uf[cur.index()]);
            self.uf[cur.index()] = root.0;
            cur = next;
        }
        root
    }

    pub fn canonicalize(&self, node: &ENode) -> ENode {
        ENode {
            op: node.op.clone(),
            children: node.children.iter().map(|&c| self.find(c)).collect(),
        }
    }

    /// Number of live e-classes.
    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    pub fn class(&self, id: ClassId) -> &EClass {
        &self.classes[&self.find(id)]
    }

    pub fn classes(&self) -> impl Iterator<Item = (ClassId, &EClass)> {
        self.classes.iter().map(|(&id, c)| (id, c))
    }

    /// Infer the type an enode would have, from its children's types.
    pub fn node_type(&self, node: &ENode) -> Result<TensorType, crate::ir::InferError> {
        let tys: Vec<TensorType> =
            node.children.iter().map(|&c| self.class(c).ty.clone()).collect();
        let refs: Vec<&TensorType> = tys.iter().collect();
        infer_type(&node.op, &refs)
    }

    /// Add an e-node (children must already be canonical or will be
    /// canonicalized). Returns the e-class containing it.
    pub fn add(&mut self, node: ENode) -> ClassId {
        let node = self.canonicalize(&node);
        if let Some(&id) = self.memo.get(&node) {
            return self.find(id);
        }
        let ty = match &node.op {
            Op::Input(_) | Op::Const(_) => {
                panic!("leaf Input/Const must be added with add_leaf(ty)")
            }
            _ => self.node_type(&node).expect("egraph add: type inference failed"),
        };
        self.add_with_type(node, ty)
    }

    /// Add a leaf (Input/Const) with an explicit type.
    pub fn add_leaf(&mut self, op: Op, ty: TensorType) -> ClassId {
        let node = ENode::leaf(op);
        if let Some(&id) = self.memo.get(&node) {
            return self.find(id);
        }
        self.add_with_type(node, ty)
    }

    pub(crate) fn add_with_type(&mut self, node: ENode, ty: TensorType) -> ClassId {
        let node = self.canonicalize(&node);
        if let Some(&id) = self.memo.get(&node) {
            return self.find(id);
        }
        self.add_with_type_unchecked(node, ty)
    }

    fn add_with_type_unchecked(&mut self, node: ENode, ty: TensorType) -> ClassId {
        let id = ClassId(self.uf.len() as u32);
        self.uf.push(id.0);
        for &c in &node.children {
            let c = self.find(c);
            self.classes.get_mut(&c).unwrap().parents.push((node.clone(), id));
        }
        self.classes.insert(id, EClass { nodes: vec![node.clone()], ty, parents: vec![] });
        self.memo.insert(node, id);
        self.n_nodes += 1;
        id
    }

    /// Merge two e-classes. Their types must agree (same value semantics).
    /// Returns the surviving root.
    pub fn union(&mut self, a: ClassId, b: ClassId) -> ClassId {
        let (ra, rb) = (self.find_compress(a), self.find_compress(b));
        if ra == rb {
            return ra;
        }
        let (ta, tb) = (&self.classes[&ra].ty, &self.classes[&rb].ty);
        debug_assert_eq!(
            (&ta.shape, ta.dtype, &ta.lanes, &ta.sbp),
            (&tb.shape, tb.dtype, &tb.lanes, &tb.sbp),
            "union of e-classes with different types"
        );
        // Merge smaller into larger.
        let (root, child) = if self.classes[&ra].nodes.len() >= self.classes[&rb].nodes.len() {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.uf[child.index()] = root.0;
        let mut removed = self.classes.remove(&child).unwrap();
        let rc = self.classes.get_mut(&root).unwrap();
        rc.nodes.append(&mut removed.nodes);
        rc.parents.append(&mut removed.parents);
        self.dirty.push(root);
        root
    }

    /// Restore congruence invariants after unions (egg-style rebuild).
    pub fn rebuild(&mut self) {
        while let Some(dirty) = self.dirty.pop() {
            let dirty = self.find(dirty);
            let parents = match self.classes.get_mut(&dirty) {
                Some(c) => std::mem::take(&mut c.parents),
                None => continue,
            };
            let mut new_parents: Vec<(ENode, ClassId)> = Vec::with_capacity(parents.len());
            for (pnode, pclass) in parents {
                let canon = self.canonicalize(&pnode);
                self.memo.remove(&pnode);
                let pclass = self.find(pclass);
                if let Some(&existing) = self.memo.get(&canon) {
                    let existing = self.find(existing);
                    if existing != pclass {
                        self.union(existing, pclass);
                    }
                } else {
                    self.memo.insert(canon.clone(), pclass);
                }
                new_parents.push((canon, self.find(pclass)));
            }
            let dirty = self.find(dirty);
            // Also canonicalize + dedup the class's own nodes.
            if let Some(c) = self.classes.get_mut(&dirty) {
                c.parents.extend(new_parents);
            }
        }
        // Canonicalize node lists (cheap full sweep; graphs here are small).
        let ids: Vec<ClassId> = self.classes.keys().copied().collect();
        for id in ids {
            if let Some(mut c) = self.classes.remove(&id) {
                let mut seen = std::collections::HashSet::new();
                c.nodes = c
                    .nodes
                    .drain(..)
                    .map(|n| self.canonicalize(&n))
                    .filter(|n| seen.insert(n.clone()))
                    .collect();
                self.classes.insert(id, c);
            }
        }
    }

    /// Import an IR [`Graph`]; returns the e-class of each graph node.
    pub fn from_graph(g: &Graph) -> (EGraph, Vec<ClassId>) {
        let mut eg = EGraph::new();
        let mut map: Vec<ClassId> = Vec::with_capacity(g.len());
        for node in &g.nodes {
            let id = if node.op.is_leaf() {
                eg.add_leaf(node.op.clone(), node.ty.clone())
            } else {
                let children = node.inputs.iter().map(|&i| map[i.index()]).collect();
                eg.add(ENode { op: node.op.clone(), children })
            };
            map.push(id);
        }
        (eg, map)
    }

    /// Reconstruct a [`Graph`] from a per-class node choice (used by the
    /// extractors). `choice` maps canonical class -> index into its nodes.
    pub fn to_graph(
        &self,
        roots: &[ClassId],
        choice: &HashMap<ClassId, ENode>,
    ) -> Result<(Graph, Vec<NodeId>), String> {
        let mut g = Graph::new();
        let mut memo: HashMap<ClassId, NodeId> = HashMap::new();
        let mut visiting: std::collections::HashSet<ClassId> = Default::default();
        let mut out_roots = Vec::new();
        for &r in roots {
            let id = self.emit(self.find(r), choice, &mut g, &mut memo, &mut visiting)?;
            g.mark_output(id);
            out_roots.push(id);
        }
        Ok((g, out_roots))
    }

    fn emit(
        &self,
        class: ClassId,
        choice: &HashMap<ClassId, ENode>,
        g: &mut Graph,
        memo: &mut HashMap<ClassId, NodeId>,
        visiting: &mut std::collections::HashSet<ClassId>,
    ) -> Result<NodeId, String> {
        let class = self.find(class);
        if let Some(&id) = memo.get(&class) {
            return Ok(id);
        }
        if !visiting.insert(class) {
            return Err(format!("cycle through e-class {}", class.0));
        }
        let node = choice.get(&class).ok_or_else(|| format!("no choice for class {}", class.0))?;
        let mut inputs = Vec::with_capacity(node.children.len());
        for &c in &node.children {
            inputs.push(self.emit(c, choice, g, memo, visiting)?);
        }
        visiting.remove(&class);
        let id = if node.op.is_leaf() {
            match &node.op {
                Op::Input(name) => {
                    let ty = &self.class(class).ty;
                    g.input(name, ty.shape.dims(), ty.dtype)
                }
                Op::Const(name) => {
                    let ty = &self.class(class).ty;
                    g.constant(name, ty.shape.dims(), ty.dtype)
                }
                _ => g.add(node.op.clone(), &[]),
            }
        } else {
            g.try_add(node.op.clone(), &inputs).map_err(|e| e.to_string())?
        };
        memo.insert(class, id);
        Ok(id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{BinaryKind, DType, Graph, UnaryKind};

    #[test]
    fn hash_consing() {
        let mut g = Graph::new();
        let a = g.input("a", &[2, 2], DType::F32);
        let e1 = g.unary(UnaryKind::Exp, a);
        g.mark_output(e1);
        let (mut eg, map) = EGraph::from_graph(&g);
        // Adding the same node again lands in the same class.
        let again = eg.add(ENode {
            op: crate::ir::Op::Unary(UnaryKind::Exp),
            children: vec![map[a.index()]],
        });
        assert_eq!(eg.find(again), eg.find(map[e1.index()]));
    }

    #[test]
    fn union_merges_and_congruence_closes() {
        // f(a), f(b): union(a, b) must make f(a) ~ f(b) after rebuild.
        let mut eg = EGraph::new();
        let ta = crate::ir::TensorType::of(&[4], DType::F32);
        let a = eg.add_leaf(crate::ir::Op::Input("a".into()), ta.clone());
        let b = eg.add_leaf(crate::ir::Op::Input("b".into()), ta.clone());
        let fa = eg.add(ENode { op: crate::ir::Op::Unary(UnaryKind::Exp), children: vec![a] });
        let fb = eg.add(ENode { op: crate::ir::Op::Unary(UnaryKind::Exp), children: vec![b] });
        assert_ne!(eg.find(fa), eg.find(fb));
        eg.union(a, b);
        eg.rebuild();
        assert_eq!(eg.find(fa), eg.find(fb), "congruence closure must merge f(a) and f(b)");
    }

    #[test]
    fn roundtrip_graph() {
        let mut g = Graph::new();
        let a = g.input("a", &[2, 3], DType::F32);
        let b = g.input("b", &[3, 4], DType::F32);
        let m = g.matmul(a, b);
        let e = g.unary(UnaryKind::Exp, m);
        let s = g.binary(BinaryKind::Add, e, e);
        g.mark_output(s);

        let (eg, map) = EGraph::from_graph(&g);
        // Choice: pick the single node of each class.
        let mut choice = HashMap::new();
        for (id, c) in eg.classes() {
            choice.insert(eg.find(id), c.nodes[0].clone());
        }
        let (g2, roots) = eg.to_graph(&[map[s.index()]], &choice).unwrap();
        assert_eq!(roots.len(), 1);
        assert_eq!(g2.node(roots[0]).ty.shape.dims(), &[2, 4]);
        // Same number of live ops.
        assert_eq!(g2.live_nodes().len(), g.live_nodes().len());
    }

    #[test]
    #[should_panic(expected = "different types")]
    #[cfg(debug_assertions)] // the check is a debug_assert (hot path)
    fn union_type_mismatch_panics() {
        let mut eg = EGraph::new();
        let a = eg.add_leaf(
            crate::ir::Op::Input("a".into()),
            crate::ir::TensorType::of(&[4], DType::F32),
        );
        let b = eg.add_leaf(
            crate::ir::Op::Input("b".into()),
            crate::ir::TensorType::of(&[5], DType::F32),
        );
        eg.union(a, b);
    }
}
