//! E-graph with equality saturation (§3.1.1).
//!
//! The e-graph stores *e-classes* (equivalence classes of values) whose
//! members are *e-nodes* (operations over child e-classes). Instead of
//! destructively rewriting the IR — which suffers from the phase-ordering
//! problem of Fig. 2 — saturation applies every rule everywhere,
//! accumulating all equivalent program versions, and a cost-based
//! extraction picks the best one afterwards.
//!
//! Two extractors are provided:
//! * [`extract::extract_greedy`] — bottom-up fixed point, fast, optimal
//!   when costs are local (used inside the saturation loop and for
//!   baselines).
//! * [`extract::extract_wpmaxsat`] — the paper's Weighted Partial MaxSAT
//!   formulation with lazy acyclicity constraints, optimal for shared
//!   sub-terms.

mod core;
mod extract;
mod saturate;

pub use self::core::{ClassId, EClass, ENode, EGraph};
pub use extract::{extract_greedy, extract_wpmaxsat, roofline_cost_fn, CostFn, Extraction};
pub use saturate::{Rewrite, Runner, RunnerLimits, RunnerReport, Subst, Tree};
