//! Extraction: pick one e-node per needed e-class minimizing total cost.
//!
//! * [`extract_greedy`] — bottom-up fixed point. Optimal for tree costs,
//!   may overcount shared subterms.
//! * [`extract_wpmaxsat`] — the paper's formulation (§3.1.1): selection
//!   variables per e-node, well-formedness as hard clauses, per-node
//!   Roofline weights as soft clauses, solved by our WPMaxSAT solver with
//!   *lazy acyclicity constraints* (solve → detect cycle → forbid →
//!   re-solve), following He et al.'s acyclic-extraction observation.

use std::collections::HashMap;

use super::{ClassId, EGraph, ENode};
use crate::ir::{Graph, NodeId, TensorType};
use crate::sat::{Lit, WpmsSolver};

/// Cost function over e-nodes: (node, children-types, own-type) -> weight.
pub type CostFn<'a> = dyn Fn(&ENode, &[&TensorType], &TensorType) -> u64 + 'a;

/// Result of extraction.
pub struct Extraction {
    pub graph: Graph,
    pub roots: Vec<NodeId>,
    /// Total cost of the selected nodes (each shared node counted once
    /// for the SAT extractor; greedy reports the DAG-aware recount too).
    pub cost: u64,
}

fn node_cost(eg: &EGraph, node: &ENode, cost: &CostFn) -> u64 {
    let tys: Vec<TensorType> = node.children.iter().map(|&c| eg.class(c).ty.clone()).collect();
    let refs: Vec<&TensorType> = tys.iter().collect();
    // Output type: the class type of the node's own class is what the
    // extractor uses; for cost purposes infer from the node itself when
    // possible, falling back to the first child's type for leaves.
    let out = eg.node_type(node).unwrap_or_else(|_| {
        tys.first().cloned().unwrap_or(TensorType::of(&[], crate::ir::DType::F32))
    });
    cost(node, &refs, &out)
}

/// Greedy bottom-up extraction.
pub fn extract_greedy(eg: &EGraph, roots: &[ClassId], cost: &CostFn) -> Extraction {
    // Fixed point: best[class] = min over nodes of (cost + sum best[child]).
    let mut best: HashMap<ClassId, (u64, ENode)> = HashMap::new();
    let mut changed = true;
    while changed {
        changed = false;
        for (id, class) in eg.classes() {
            let id = eg.find(id);
            for node in &class.nodes {
                let mut total = node_cost(eg, node, cost) as u128;
                let mut ok = true;
                for &c in &node.children {
                    match best.get(&eg.find(c)) {
                        Some((bc, _)) => total += *bc as u128,
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                if !ok {
                    continue;
                }
                let total = total.min(u64::MAX as u128) as u64;
                let better = best.get(&id).map(|(b, _)| total < *b).unwrap_or(true);
                if better {
                    best.insert(id, (total, node.clone()));
                    changed = true;
                }
            }
        }
    }
    let choice: HashMap<ClassId, ENode> =
        best.iter().map(|(&id, (_, n))| (id, n.clone())).collect();
    let (graph, out_roots) =
        eg.to_graph(roots, &choice).expect("greedy extraction produced a cycle");
    // DAG-aware recount: each selected class counted once.
    let mut counted: u64 = 0;
    let mut seen = std::collections::HashSet::new();
    let mut stack: Vec<ClassId> = roots.iter().map(|&r| eg.find(r)).collect();
    while let Some(c) = stack.pop() {
        if !seen.insert(c) {
            continue;
        }
        if let Some((_, n)) = best.get(&c) {
            counted += node_cost(eg, n, cost);
            stack.extend(n.children.iter().map(|&ch| eg.find(ch)));
        }
    }
    Extraction { graph, roots: out_roots, cost: counted }
}

/// WPMaxSAT extraction with lazy acyclicity. Falls back to greedy if the
/// MaxSAT solve fails (should not happen on well-formed e-graphs) or if
/// the instance exceeds the practical SAT size budget.
pub fn extract_wpmaxsat(eg: &EGraph, roots: &[ClassId], cost: &CostFn) -> Extraction {
    if eg.n_nodes > 1200 {
        return extract_greedy(eg, roots, cost);
    }
    // Enumerate canonical classes and their nodes.
    let mut class_ids: Vec<ClassId> = eg.classes().map(|(id, _)| eg.find(id)).collect();
    class_ids.sort();
    class_ids.dedup();
    let class_index: HashMap<ClassId, usize> =
        class_ids.iter().enumerate().map(|(i, &c)| (c, i)).collect();

    // Node list per class with costs.
    struct NodeVar {
        class: ClassId,
        node: ENode,
        cost: u64,
    }
    let mut node_vars: Vec<NodeVar> = Vec::new();
    let mut class_nodes: Vec<Vec<usize>> = vec![Vec::new(); class_ids.len()];
    for &cid in &class_ids {
        for node in &eg.class(cid).nodes {
            let idx = node_vars.len();
            node_vars.push(NodeVar {
                class: cid,
                node: node.clone(),
                cost: node_cost(eg, node, cost),
            });
            class_nodes[class_index[&cid]].push(idx);
        }
    }

    // Variables: x_i per node, y_c per class. Layout: nodes then classes.
    let n_nodes = node_vars.len();
    let var_node = |i: usize| i as u32;
    let var_class = |c: usize| (n_nodes + c) as u32;

    let mut banned_combos: Vec<Vec<usize>> = Vec::new(); // lazy cycle cuts
    for _attempt in 0..24 {
        let mut w = WpmsSolver::new();
        w.ensure_vars((n_nodes + class_ids.len()) as u32);
        // Roots must be selected.
        for &r in roots {
            let r = eg.find(r);
            w.add_hard(&[Lit::pos(var_class(class_index[&r]))]);
        }
        // y_c -> OR x_i.
        for (ci, nodes) in class_nodes.iter().enumerate() {
            let mut cl: Vec<Lit> = vec![Lit::neg(var_class(ci))];
            cl.extend(nodes.iter().map(|&i| Lit::pos(var_node(i))));
            w.add_hard(&cl);
        }
        // x_i -> y_{class(i)} and x_i -> y_child for each child.
        for (i, nv) in node_vars.iter().enumerate() {
            w.add_hard(&[Lit::neg(var_node(i)), Lit::pos(var_class(class_index[&nv.class]))]);
            for &c in &nv.node.children {
                let c = eg.find(c);
                w.add_hard(&[Lit::neg(var_node(i)), Lit::pos(var_class(class_index[&c]))]);
            }
        }
        // Lazy cycle cuts: at least one node of the cycle must be off.
        for combo in &banned_combos {
            let cl: Vec<Lit> = combo.iter().map(|&i| Lit::neg(var_node(i))).collect();
            w.add_hard(&cl);
        }
        // Soft: not selecting node i is free; selecting costs its weight.
        for (i, nv) in node_vars.iter().enumerate() {
            w.add_soft(&[Lit::neg(var_node(i))], nv.cost.max(1));
        }

        let Some(res) = w.solve() else {
            break; // fall through to greedy
        };

        // Build per-class choice: cheapest selected node.
        let mut choice: HashMap<ClassId, (u64, usize)> = HashMap::new();
        for (i, nv) in node_vars.iter().enumerate() {
            if res.model[i] {
                let e = choice.entry(nv.class).or_insert((nv.cost, i));
                if nv.cost < e.0 {
                    *e = (nv.cost, i);
                }
            }
        }
        // Cycle check via iterative colored DFS from roots over the
        // chosen nodes.
        let mut state: HashMap<ClassId, u8> = HashMap::new(); // 1=visiting 2=done
        let mut cycle: Option<Vec<usize>> = None;
        for &r in roots {
            if cycle.is_some() {
                break;
            }
            // Stack entries: (class, entered?).
            let mut stack: Vec<(ClassId, bool)> = vec![(eg.find(r), false)];
            let mut path: Vec<usize> = Vec::new();
            while let Some((c, entered)) = stack.pop() {
                if entered {
                    state.insert(c, 2);
                    path.pop();
                    continue;
                }
                match state.get(&c) {
                    Some(2) => continue,
                    Some(1) => {
                        cycle = Some(path.clone());
                        break;
                    }
                    _ => {}
                }
                state.insert(c, 1);
                stack.push((c, true));
                if let Some(&(_, i)) = choice.get(&c) {
                    path.push(i);
                    for &ch in &node_vars[i].node.children {
                        stack.push((eg.find(ch), false));
                    }
                } else {
                    path.push(usize::MAX); // placeholder so pops balance
                }
            }
        }
        if let Some(c) = &mut cycle {
            c.retain(|&i| i != usize::MAX);
        }
        match cycle {
            Some(combo) if !combo.is_empty() => {
                banned_combos.push(combo);
                continue;
            }
            _ => {
                let choice_nodes: HashMap<ClassId, ENode> = choice
                    .iter()
                    .map(|(&c, &(_, i))| (c, node_vars[i].node.clone()))
                    .collect();
                if let Ok((graph, out_roots)) = eg.to_graph(roots, &choice_nodes) {
                    // Count cost of reachable selected nodes only.
                    let mut total = 0u64;
                    let mut seen = std::collections::HashSet::new();
                    let mut stack: Vec<ClassId> = roots.iter().map(|&r| eg.find(r)).collect();
                    while let Some(c) = stack.pop() {
                        if !seen.insert(c) {
                            continue;
                        }
                        if let Some(&(cost_i, i)) = choice.get(&c) {
                            total += cost_i;
                            stack.extend(node_vars[i].node.children.iter().map(|&ch| eg.find(ch)));
                        }
                    }
                    return Extraction { graph, roots: out_roots, cost: total };
                }
            }
        }
    }
    extract_greedy(eg, roots, cost)
}

/// Default cost: Roofline weight per node on `machine` (§3.1.1).
pub fn roofline_cost_fn(
    machine: &crate::cost::MachineSpec,
) -> impl Fn(&ENode, &[&TensorType], &TensorType) -> u64 + '_ {
    move |node, ins, out| crate::cost::enode_cost(&node.op, ins, out, machine).ns
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::egraph::Tree;
    use crate::ir::{DType, Graph, Op, UnaryKind};

    fn unit_cost(node: &ENode, _ins: &[&TensorType], _out: &TensorType) -> u64 {
        if node.op.is_leaf() {
            1
        } else {
            10
        }
    }

    #[test]
    fn greedy_picks_cheaper_variant() {
        // Class with two equivalent nodes: exp(a) and an artificially
        // cheap alias (neg(a) unioned in by hand with a cost override).
        let mut g = Graph::new();
        let a = g.input("a", &[4], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        g.mark_output(e);
        let (mut eg, map) = EGraph::from_graph(&g);
        let neg = Tree::node(Op::Unary(UnaryKind::Neg), vec![Tree::class(map[a.index()])])
            .add_to(&mut eg);
        eg.union(map[e.index()], neg);
        eg.rebuild();
        let cost = |n: &ENode, _: &[&TensorType], _: &TensorType| -> u64 {
            match n.op {
                Op::Unary(UnaryKind::Neg) => 2,
                Op::Unary(UnaryKind::Exp) => 50,
                _ => 1,
            }
        };
        let ex = extract_greedy(&eg, &[map[e.index()]], &cost);
        let has_neg = ex.graph.nodes.iter().any(|n| matches!(n.op, Op::Unary(UnaryKind::Neg)));
        assert!(has_neg, "greedy must pick the cheap variant");
    }

    #[test]
    fn wpmaxsat_matches_greedy_on_tree() {
        let mut g = Graph::new();
        let a = g.input("a", &[8], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        let n = g.unary(UnaryKind::Neg, e);
        g.mark_output(n);
        let (eg, map) = EGraph::from_graph(&g);
        let ge = extract_greedy(&eg, &[map[n.index()]], &unit_cost);
        let se = extract_wpmaxsat(&eg, &[map[n.index()]], &unit_cost);
        assert_eq!(ge.cost, se.cost);
        assert_eq!(ge.graph.live_nodes().len(), se.graph.live_nodes().len());
    }

    #[test]
    fn wpmaxsat_beats_greedy_on_shared_subterm() {
        // Two roots sharing an expensive subterm. The greedy *tree* cost
        // double-counts the shared node when comparing variants; the SAT
        // extractor optimizes the true DAG cost. Construct a class where
        // variant A is locally cheap but blocks sharing, variant B is
        // shared by both roots.
        let mut g = Graph::new();
        let a = g.input("a", &[64, 64], DType::F32);
        // Shared expensive node exp(a).
        let e = g.unary(UnaryKind::Exp, a);
        let r1 = g.unary(UnaryKind::Neg, e);
        let r2 = g.unary(UnaryKind::Sqrt, e);
        g.mark_output(r1);
        g.mark_output(r2);
        let (mut eg, map) = EGraph::from_graph(&g);
        // Alternative for r1: abs(a) (avoids exp but costs 55 alone).
        let alt = Tree::node(Op::Unary(UnaryKind::Abs), vec![Tree::class(map[a.index()])])
            .add_to(&mut eg);
        eg.union(map[r1.index()], alt);
        eg.rebuild();
        let cost = |n: &ENode, _: &[&TensorType], _: &TensorType| -> u64 {
            match n.op {
                Op::Unary(UnaryKind::Exp) => 50,
                Op::Unary(UnaryKind::Abs) => 55,
                Op::Unary(UnaryKind::Neg) => 1,
                Op::Unary(UnaryKind::Sqrt) => 1,
                _ => 1,
            }
        };
        let roots = [map[r1.index()], map[r2.index()]];
        let se = extract_wpmaxsat(&eg, &roots, &cost);
        // exp is shared: neg(exp(a)) + sqrt(exp(a)) = 50+1+1+leaf, while
        // abs path = 55+1(sqrt)+50(exp still needed for r2)+leaf.
        // Optimal total: 1 (leaf) + 50 + 1 + 1 = 53.
        assert_eq!(se.cost, 53, "SAT extraction must share the exp node");
        let has_abs = se.graph.nodes.iter().any(|n| matches!(n.op, Op::Unary(UnaryKind::Abs)));
        assert!(!has_abs);
    }
}
