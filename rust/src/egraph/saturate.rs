//! Equality-saturation runner and the rewrite-rule interface.

use std::collections::HashMap;

use super::{ClassId, EGraph, ENode};
use crate::ir::TensorType;

/// A tree of new nodes a rewrite wants to add. Leaves may reference
/// existing e-classes, so rules can splice into the graph.
#[derive(Debug, Clone)]
pub enum Tree {
    /// A new node with child trees.
    Node(crate::ir::Op, Vec<Tree>),
    /// An existing e-class.
    Class(ClassId),
    /// A new leaf with an explicit type (Input/Const clones).
    Leaf(crate::ir::Op, TensorType),
}

impl Tree {
    pub fn class(id: ClassId) -> Tree {
        Tree::Class(id)
    }

    pub fn node(op: crate::ir::Op, children: Vec<Tree>) -> Tree {
        Tree::Node(op, children)
    }

    /// Add this tree to the e-graph, returning the root e-class.
    pub fn add_to(&self, eg: &mut EGraph) -> ClassId {
        match self {
            Tree::Class(id) => eg.find(*id),
            Tree::Leaf(op, ty) => eg.add_leaf(op.clone(), ty.clone()),
            Tree::Node(op, children) => {
                let ch: Vec<ClassId> = children.iter().map(|t| t.add_to(eg)).collect();
                eg.add(ENode { op: op.clone(), children: ch })
            }
        }
    }
}

/// Variable bindings produced by a match (kept for debugging/reporting).
pub type Subst = HashMap<&'static str, ClassId>;

/// A rewrite rule. `matches` inspects one e-node and returns equivalent
/// trees to union with the node's class. Rules never mutate the e-graph
/// while matching — saturation applies all matches afterwards, which is
/// exactly what makes the engine non-destructive (Observation 1).
pub trait Rewrite {
    fn name(&self) -> &'static str;

    /// Return equivalent trees for `node` (member of `class`).
    fn matches(&self, eg: &EGraph, class: ClassId, node: &ENode) -> Vec<Tree>;
}

/// Saturation limits.
#[derive(Debug, Clone, Copy)]
pub struct RunnerLimits {
    pub max_iters: usize,
    pub max_nodes: usize,
}

impl Default for RunnerLimits {
    fn default() -> Self {
        RunnerLimits { max_iters: 12, max_nodes: 50_000 }
    }
}

/// Report of one saturation run.
#[derive(Debug, Clone, Default)]
pub struct RunnerReport {
    pub iterations: usize,
    pub saturated: bool,
    pub nodes: usize,
    pub classes: usize,
    /// Applications per rule name.
    pub applications: HashMap<&'static str, usize>,
}

/// The equality-saturation driver: repeatedly match all rules against all
/// (class, node) pairs, apply the produced unions, rebuild, and stop at a
/// fixed point or when limits are hit.
pub struct Runner<'a> {
    pub egraph: &'a mut EGraph,
    pub limits: RunnerLimits,
}

impl<'a> Runner<'a> {
    pub fn new(egraph: &'a mut EGraph) -> Self {
        Runner { egraph, limits: RunnerLimits::default() }
    }

    pub fn with_limits(mut self, limits: RunnerLimits) -> Self {
        self.limits = limits;
        self
    }

    pub fn run(self, rules: &[&dyn Rewrite]) -> RunnerReport {
        let mut report = RunnerReport::default();
        for iter in 0..self.limits.max_iters {
            report.iterations = iter + 1;
            // Match phase: collect (class, tree, rule) triples.
            let mut pending: Vec<(ClassId, Tree, &'static str)> = Vec::new();
            let snapshot: Vec<(ClassId, Vec<ENode>)> = self
                .egraph
                .classes()
                .map(|(id, c)| (id, c.nodes.clone()))
                .collect();
            for (class, nodes) in &snapshot {
                for node in nodes {
                    for rule in rules {
                        for tree in rule.matches(self.egraph, *class, node) {
                            pending.push((*class, tree, rule.name()));
                        }
                    }
                }
            }
            // Apply phase.
            let before_nodes = self.egraph.n_nodes;
            let mut changed = false;
            for (class, tree, rule_name) in pending {
                let new_root = tree.add_to(self.egraph);
                let class = self.egraph.find(class);
                if self.egraph.find(new_root) != class {
                    self.egraph.union(class, new_root);
                    changed = true;
                    *report.applications.entry(rule_name).or_default() += 1;
                }
                if self.egraph.n_nodes > self.limits.max_nodes {
                    break;
                }
            }
            self.egraph.rebuild();
            let grew = self.egraph.n_nodes > before_nodes;
            if !changed && !grew {
                report.saturated = true;
                break;
            }
            if self.egraph.n_nodes > self.limits.max_nodes {
                break;
            }
        }
        report.nodes = self.egraph.n_nodes;
        report.classes = self.egraph.num_classes();
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, Graph, Op, UnaryKind};

    /// Toy rule: exp(x) also equals exp(x) wrapped in two negs (saturates
    /// after one application thanks to hash-consing).
    struct DoubleNeg;

    impl Rewrite for DoubleNeg {
        fn name(&self) -> &'static str {
            "double-neg"
        }

        fn matches(&self, _eg: &EGraph, _class: ClassId, node: &ENode) -> Vec<Tree> {
            if let Op::Unary(UnaryKind::Exp) = node.op {
                vec![Tree::node(
                    Op::Unary(UnaryKind::Neg),
                    vec![Tree::node(
                        Op::Unary(UnaryKind::Neg),
                        vec![Tree::node(
                            Op::Unary(UnaryKind::Exp),
                            vec![Tree::class(node.children[0])],
                        )],
                    )],
                )]
            } else {
                vec![]
            }
        }
    }

    #[test]
    fn saturates_and_reports() {
        let mut g = Graph::new();
        let a = g.input("a", &[4], DType::F32);
        let e = g.unary(UnaryKind::Exp, a);
        g.mark_output(e);
        let (mut eg, _) = EGraph::from_graph(&g);
        let report = Runner::new(&mut eg).run(&[&DoubleNeg]);
        assert!(report.saturated, "tiny rule set must saturate");
        assert!(report.applications["double-neg"] >= 1);
        assert!(report.nodes >= 3);
    }

    #[test]
    fn iter_limit_stops_before_saturation() {
        // Transpose rules on the Fig. 2 graph need several iterations to
        // saturate; max_iters = 1 must stop early and report !saturated.
        use crate::ir::BinaryKind;
        let mut g = Graph::new();
        let a = g.input("A", &[8, 8], DType::F32);
        let b = g.input("B", &[8, 8], DType::F32);
        let ta = g.transpose(a, &[1, 0]);
        let tb = g.transpose(b, &[1, 0]);
        let ub = g.unary(UnaryKind::Exp, tb);
        let sum = g.binary(BinaryKind::Add, ta, ub);
        let out = g.transpose(sum, &[1, 0]);
        g.mark_output(out);
        let (mut eg, _) = EGraph::from_graph(&g);
        let rules = crate::rewrite::transpose_rules();
        let refs: Vec<&dyn Rewrite> = rules.iter().map(|r| r.as_ref()).collect();
        let report = Runner::new(&mut eg)
            .with_limits(RunnerLimits { max_iters: 1, max_nodes: 100_000 })
            .run(&refs);
        assert_eq!(report.iterations, 1);
        assert!(!report.saturated, "one iteration cannot reach the fixed point");
    }

    #[test]
    fn node_limit_bounds_growth() {
        // With a tiny node budget the runner must stop promptly even
        // though the rule set would keep growing the graph.
        use crate::ir::BinaryKind;
        let mut g = Graph::new();
        let a = g.input("A", &[8, 8], DType::F32);
        let b = g.input("B", &[8, 8], DType::F32);
        let ta = g.transpose(a, &[1, 0]);
        let tb = g.transpose(b, &[1, 0]);
        let ub = g.unary(UnaryKind::Exp, tb);
        let sum = g.binary(BinaryKind::Add, ta, ub);
        g.mark_output(sum);
        let (mut eg, _) = EGraph::from_graph(&g);
        let rules = crate::rewrite::transpose_rules();
        let refs: Vec<&dyn Rewrite> = rules.iter().map(|r| r.as_ref()).collect();
        let report = Runner::new(&mut eg)
            .with_limits(RunnerLimits { max_iters: 50, max_nodes: 10 })
            .run(&refs);
        assert!(report.nodes <= 30, "node limit must bound growth, got {}", report.nodes);
    }
}
