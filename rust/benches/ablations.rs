//! Ablation benches for the design choices DESIGN.md §6 calls out:
//!
//! * e-graph saturation + WPMaxSAT vs destructive greedy rewriting
//! * MetaPackOperation pass-through layout vs kernel-local packing
//! * SBP SAT extraction (memory-constrained) vs greedy / all-Broadcast
//! * MCTS+MINLP vs random structural search vs fixed-tile heuristic
//! * SAT bin-packing memory planner vs first-fit vs bump allocator
//!
//! Run: `cargo bench --bench ablations`

mod bench_util;

use bench_util::row;
use nncase_repro::codegen::{plan_memory, PlannerKind};
use nncase_repro::cost::MachineSpec;
use nncase_repro::dist::{build_dist_egraph, extract_dist, Placement};
use nncase_repro::egraph::{
    extract_greedy, extract_wpmaxsat, roofline_cost_fn, EGraph, Runner,
};
use nncase_repro::ir::{BinaryKind, DType, Graph, Op, TensorType, UnaryKind};
use nncase_repro::model::{decode_graph, Qwen3Config};
use nncase_repro::rewrite::greedy::{count_transposes, greedy_rewrite, GreedyOrder};
use nncase_repro::rewrite::{all_rules, pack::PackOptions, transpose_rules};
use nncase_repro::schedule::{
    autoschedule, solve_parametric, subgraph_to_tileops, MctsConfig, MinlpConfig, TiledState,
};
use nncase_repro::util::Rng;

fn fig2_graph() -> (Graph, nncase_repro::ir::NodeId) {
    let mut g = Graph::new();
    let a = g.input("A", &[256, 256], DType::F32);
    let b = g.input("B", &[256, 256], DType::F32);
    let ta = g.transpose(a, &[1, 0]);
    let tb = g.transpose(b, &[1, 0]);
    let ub = g.unary(UnaryKind::Exp, tb);
    let sum = g.binary(BinaryKind::Add, ta, ub);
    let out = g.transpose(sum, &[1, 0]);
    g.mark_output(out);
    (g, out)
}

fn ablation_egraph(machine: &MachineSpec) {
    println!("== ablation: e-graph vs greedy rewriting (Fig. 2) ==");
    let (g, out) = fig2_graph();
    let (gl, _) = greedy_rewrite(&g, GreedyOrder::LeftFirst);
    let (gr, _) = greedy_rewrite(&g, GreedyOrder::RightFirst);
    row("greedy left-first transposes", count_transposes(&gl));
    row("greedy right-first transposes", count_transposes(&gr));
    let (mut eg, map) = EGraph::from_graph(&g);
    let rules = transpose_rules();
    let refs: Vec<&dyn nncase_repro::egraph::Rewrite> =
        rules.iter().map(|r| r.as_ref()).collect();
    Runner::new(&mut eg).run(&refs);
    let cost = roofline_cost_fn(machine);
    let sat = extract_wpmaxsat(&eg, &[map[out.index()]], &cost);
    let grd = extract_greedy(&eg, &[map[out.index()]], &cost);
    row("egraph+WPMaxSAT transposes", count_transposes(&sat.graph));
    row("egraph+WPMaxSAT cost (ns)", sat.cost);
    row("egraph+greedy-extract cost (ns)", grd.cost);
    assert_eq!(count_transposes(&sat.graph), 0);
    println!();
}

fn ablation_vectorize(machine: &MachineSpec) {
    println!("== ablation: pass-through layout vs kernel-local packing (Fig. 3) ==");
    let mut g = Graph::new();
    let q = g.input("Q", &[64, 64], DType::F32);
    let k = g.input("K", &[64, 64], DType::F32);
    let v = g.input("V", &[64, 64], DType::F32);
    let s = g.matmul(q, k);
    let e = g.unary(UnaryKind::Exp, s);
    let o = g.matmul(e, v);
    g.mark_output(o);
    let (mut eg, map) = EGraph::from_graph(&g);
    let rules = all_rules(&PackOptions::default());
    let refs: Vec<&dyn nncase_repro::egraph::Rewrite> =
        rules.iter().map(|r| r.as_ref()).collect();
    Runner::new(&mut eg).run(&refs);
    let cost = roofline_cost_fn(machine);
    let global = extract_wpmaxsat(&eg, &[map[o.index()]], &cost);
    // Kernel-local packing: every packed op pays its own pack+unpack —
    // modeled by pricing a pack/unpack pair around each of the 3 compute
    // ops (what IPEX-style local optimization does).
    let packs = |graph: &Graph| {
        graph
            .live_nodes()
            .iter()
            .filter(|&&id| {
                matches!(graph.node(id).op, Op::Pack { .. } | Op::Unpack { .. })
            })
            .count()
    };
    row("global (e-graph) pack+unpack ops", packs(&global.graph));
    row("kernel-local pack+unpack ops (2 per op)", 3 * 2);
    let conv_bytes = |n: usize| n as u64 * (64 * 64 * 4) as u64 * 2;
    row(
        "conversion traffic: global",
        format!("{} KiB", conv_bytes(packs(&global.graph)) / 1024),
    );
    row("conversion traffic: kernel-local", format!("{} KiB", conv_bytes(6) / 1024));
    assert!(packs(&global.graph) < 6);
    println!();
}

fn ablation_dist(machine: &MachineSpec) {
    println!("== ablation: SBP extraction strategies (MLP, 4 devices) ==");
    let mut g = Graph::new();
    let x = g.input("x", &[8, 512], DType::F32);
    let w1 = g.constant("w1", &[512, 2048], DType::F32);
    let w2 = g.constant("w2", &[2048, 512], DType::F32);
    let h = g.matmul(x, w1);
    let a = g.unary(UnaryKind::Silu, h);
    let out = g.matmul(a, w2);
    g.mark_output(out);
    let d = build_dist_egraph(&g, &Placement::line(4));
    let sat = extract_dist(&d, machine, u64::MAX / 4, true).unwrap();
    let greedy = extract_dist(&d, machine, u64::MAX / 4, false).unwrap();
    row("SAT total (us)", format!("{:.1}", sat.total_ns as f64 / 1e3));
    row("SAT comm (us)", format!("{:.1}", sat.comm_ns as f64 / 1e3));
    row("greedy total (us)", format!("{:.1}", greedy.total_ns as f64 / 1e3));
    row(
        "SAT weight shard/device",
        nncase_repro::util::human_bytes(sat.weight_bytes_per_device as usize),
    );
    // All-Broadcast reference: every device holds all weights.
    let full: u64 = 2 * 512 * 2048 * 4;
    row(
        "all-Broadcast weights/device",
        nncase_repro::util::human_bytes(full as usize),
    );
    assert!(sat.weight_bytes_per_device <= full);
    println!();
}

fn ablation_schedule(machine: &MachineSpec) {
    println!("== ablation: MCTS+MINLP vs random search vs fixed tiles ==");
    let mut g = Graph::new();
    let q = g.input("Q", &[512, 256], DType::F32);
    let k = g.input("K", &[256, 512], DType::F32);
    let v = g.input("V", &[512, 256], DType::F32);
    let t1 = g.matmul(q, k);
    let t2 = g.unary(UnaryKind::Exp, t1);
    let o = g.matmul(t2, v);
    g.mark_output(o);
    let nodes = g.live_nodes();
    let mk = || TiledState::initial(subgraph_to_tileops(&g, &nodes), machine.caches.len());

    let mcts = autoschedule(mk(), machine, MctsConfig { iterations: 120, ..Default::default() })
        .unwrap();
    row("MCTS+MINLP latency (us)", format!("{:.1}", mcts.solution.latency_s * 1e6));

    // Random structural search with the same evaluation budget.
    let mut rng = Rng::new(42);
    let mut best_rand = f64::INFINITY;
    for _ in 0..120 {
        let mut s = mk();
        for _ in 0..rng.below(4) {
            let acts = s.legal_actions();
            if acts.is_empty() {
                break;
            }
            let a = acts[rng.below(acts.len())].clone();
            s = s.apply(&a);
        }
        if let Some(sol) = solve_parametric(&s, machine, &MinlpConfig::default()) {
            best_rand = best_rand.min(sol.latency_s);
        }
    }
    row("random search latency (us)", format!("{:.1}", best_rand * 1e6));

    // Fixed-tile heuristic: initial structure, default MINLP on the
    // unfused state only (no structural exploration).
    let fixed = solve_parametric(&mk(), machine, &MinlpConfig::default()).unwrap();
    row("fixed structure latency (us)", format!("{:.1}", fixed.latency_s * 1e6));
    assert!(mcts.solution.latency_s <= fixed.latency_s * 1.0001);
    assert!(mcts.solution.latency_s <= best_rand * 1.25, "MCTS within 25% of random-best");
    println!();
}

fn ablation_memplan() {
    println!("== ablation: memory planners on the tiny decode graph ==");
    let g = decode_graph(&Qwen3Config::tiny(), 7, None);
    let bufs = nncase_repro::codegen::bufferize(&g);
    let live = nncase_repro::codegen::Liveness::compute(&g, &bufs);
    for kind in [PlannerKind::Bump, PlannerKind::FirstFit, PlannerKind::SatOptimal] {
        let plan = plan_memory(&bufs, &live, kind);
        row(
            &format!("{kind:?} arena"),
            nncase_repro::util::human_bytes(plan.arena_bytes),
        );
    }
    let bump = plan_memory(&bufs, &live, PlannerKind::Bump).arena_bytes;
    let ff = plan_memory(&bufs, &live, PlannerKind::FirstFit).arena_bytes;
    assert!(ff < bump / 2, "liveness reuse must at least halve the arena");
    println!();
}

fn ablation_f16(machine: &MachineSpec) {
    println!("== ablation: dtype sweep (nncase, 1T, simulator) ==");
    use nncase_repro::sim::{simulate_decode, Framework};
    for (name, cfg) in [
        ("0.6B F32", Qwen3Config::qwen3_0_6b(DType::F32)),
        ("0.6B F16", Qwen3Config::qwen3_0_6b(DType::F16)),
        ("0.6B BF16", Qwen3Config::qwen3_0_6b(DType::BF16)),
        ("1.7B F16", Qwen3Config::qwen3_1_7b(DType::F16)),
    ] {
        let s = simulate_decode(&cfg, 1, &Framework::nncase(), machine, 8);
        row(&format!("nncase {name} (tok/s)"), format!("{:.2}", s.tokens_per_s));
    }
    println!();
}

fn main() {
    let machine = MachineSpec::ryzen_5900x();
    ablation_egraph(&machine);
    ablation_vectorize(&machine);
    ablation_dist(&machine);
    ablation_schedule(&machine);
    ablation_memplan();
    ablation_f16(&machine);
    println!("ablations OK");
}
