//! Minimal benchmark harness (criterion is not in the offline vendor
//! set): median-of-N wall-clock timing with warmup, ns-resolution.

use std::time::Instant;

/// Time `f` with `warmup` + `reps` runs; returns median seconds.
pub fn time_median<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<f64> = (0..reps)
        .map(|_| {
            let t = Instant::now();
            std::hint::black_box(f());
            t.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Pretty time.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.0} ns", s * 1e9)
    }
}

/// Print one result row.
pub fn row(name: &str, value: impl std::fmt::Display) {
    println!("{name:<48} {value}");
}
