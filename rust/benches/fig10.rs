//! Figure 10 regeneration: multi-core (4T/8T) decode throughput, with
//! §4.2's shape checks: nncase overtakes the hand-optimized llama.cpp,
//! the 1T→4T scaling gap, and the 8T bandwidth wall.
//!
//! Run: `cargo bench --bench fig10`

use nncase_repro::cost::MachineSpec;
use nncase_repro::ir::DType;
use nncase_repro::model::Qwen3Config;
use nncase_repro::sim::figures::{fig10_table, render};
use nncase_repro::sim::{simulate_decode, Framework};

fn main() {
    let machine = MachineSpec::ryzen_5900x();
    let rows = fig10_table(&machine);
    println!("{}", render(&rows, "Figure 10 — multi-core (4T/8T) token throughput"));

    let get = |model: &str, fw: &str, t: usize| {
        rows.iter()
            .find(|r| r.model == model && r.framework == fw && r.threads == t)
            .map(|r| r.tokens_per_s)
            .unwrap()
    };

    // Crossover: nncase >= llama.cpp at 4T and 8T (paper: 23.5 vs 23.2
    // on 0.6B-F16-4T; 8.85 vs 8.34 on 1.7B-F16-4T).
    for model in ["Qwen3-0.6B-f16", "Qwen3-1.7B-f16"] {
        for t in [4usize, 8] {
            let (n, l) = (get(model, "nncase", t), get(model, "llama.cpp", t));
            assert!(n > l, "{model} {t}T: nncase {n:.2} must overtake llama.cpp {l:.2}");
            println!("{model} {t}T: nncase/llama.cpp = {:.3} (paper ~1.01-1.06)", n / l);
        }
    }

    // Scaling efficiency 1T -> 4T on 1.7B (paper: +74% nncase vs +32%
    // llama.cpp).
    let cfg = Qwen3Config::qwen3_1_7b(DType::F16);
    let gain = |f: &Framework| {
        simulate_decode(&cfg, 4, f, &machine, 8).tokens_per_s
            / simulate_decode(&cfg, 1, f, &machine, 8).tokens_per_s
    };
    let gn = (gain(&Framework::nncase()) - 1.0) * 100.0;
    let gl = (gain(&Framework::llamacpp()) - 1.0) * 100.0;
    println!(
        "1.7B 1T->4T scaling: nncase +{gn:.0}% (paper +74%), llama.cpp +{gl:.0}% (paper +32%)"
    );
    assert!(gn > gl);

    // Bandwidth wall: 8T ~ 4T.
    let t4 = get("Qwen3-0.6B-f16", "nncase", 4);
    let t8 = get("Qwen3-0.6B-f16", "nncase", 8);
    println!("0.6B-F16 nncase 8T/4T = {:.3} (paper: 23.98/23.5 = 1.02)", t8 / t4);
    assert!(t8 / t4 < 1.3, "8T must sit on the bandwidth wall");
    println!("\nfig10 shape checks OK");
}
