//! Hot-path microbenchmarks — the §Perf instrumentation.
//!
//! Covers each layer's hot loop:
//! * L3 compiler: saturation, WPMaxSAT extraction, distributed e-graph
//!   build+extract, MINLP solve, memory planning.
//! * L3 runtime: NTT blocked matmul GFLOP/s (vs naive), GEMV bandwidth,
//!   real decode step latency at 1/2/4 threads.
//!
//! Run: `cargo bench --bench hotpaths`

mod bench_util;

use bench_util::{fmt_time, row, time_median};
use nncase_repro::codegen::{plan_memory, PlannerKind};
use nncase_repro::coordinator::Qwen3Engine;
use nncase_repro::cost::MachineSpec;
use nncase_repro::dist::{build_dist_egraph, extract_dist, Placement};
use nncase_repro::egraph::{extract_wpmaxsat, roofline_cost_fn, EGraph, Runner};
use nncase_repro::ir::{DType, Graph, UnaryKind};
use nncase_repro::model::{decode_graph, Qwen3Config, Qwen3Weights};
use nncase_repro::ntt::{gemv, matmul_blocked, matmul_naive, Tensor};
use nncase_repro::rewrite::{all_rules, pack::PackOptions};
use nncase_repro::schedule::{solve_parametric, subgraph_to_tileops, MinlpConfig, TiledState};
use nncase_repro::util::Rng;

fn attention_graph(n: usize) -> Graph {
    let mut g = Graph::new();
    let q = g.input("Q", &[n, n], DType::F32);
    let k = g.input("K", &[n, n], DType::F32);
    let v = g.input("V", &[n, n], DType::F32);
    let s = g.matmul(q, k);
    let e = g.unary(UnaryKind::Exp, s);
    let o = g.matmul(e, v);
    g.mark_output(o);
    g
}

fn main() {
    let machine = MachineSpec::ryzen_5900x();

    println!("== L3 compiler hot paths ==");
    let g = attention_graph(64);
    let t = time_median(1, 5, || {
        let (mut eg, _) = EGraph::from_graph(&g);
        let rules = all_rules(&PackOptions::default());
        let refs: Vec<&dyn nncase_repro::egraph::Rewrite> =
            rules.iter().map(|r| r.as_ref()).collect();
        Runner::new(&mut eg).run(&refs);
        eg.n_nodes
    });
    row("saturation (attention, Tables 1+2)", fmt_time(t));

    let (mut eg, map) = EGraph::from_graph(&g);
    let rules = all_rules(&PackOptions::default());
    let refs: Vec<&dyn nncase_repro::egraph::Rewrite> =
        rules.iter().map(|r| r.as_ref()).collect();
    Runner::new(&mut eg).run(&refs);
    let roots = [map[g.outputs[0].index()]];
    let cost = roofline_cost_fn(&machine);
    let t = time_median(1, 5, || extract_wpmaxsat(&eg, &roots, &cost).cost);
    row("WPMaxSAT extraction", fmt_time(t));
    let t = time_median(1, 20, || {
        nncase_repro::egraph::extract_greedy(&eg, &roots, &cost).cost
    });
    row("greedy extraction", fmt_time(t));

    let mlp = {
        let mut g = Graph::new();
        let x = g.input("x", &[8, 512], DType::F32);
        let w1 = g.constant("w1", &[512, 2048], DType::F32);
        let w2 = g.constant("w2", &[2048, 512], DType::F32);
        let h = g.matmul(x, w1);
        let a = g.unary(UnaryKind::Silu, h);
        let o = g.matmul(a, w2);
        g.mark_output(o);
        g
    };
    let t = time_median(1, 3, || {
        let d = build_dist_egraph(&mlp, &Placement::line(4));
        extract_dist(&d, &machine, u64::MAX / 4, true).unwrap().total_ns
    });
    row("dist e-graph build + SAT extract (4 dev)", fmt_time(t));

    let ops = subgraph_to_tileops(&g, &g.live_nodes());
    let state = TiledState::initial(ops, machine.caches.len());
    let t = time_median(1, 5, || {
        solve_parametric(&state, &machine, &MinlpConfig::default()).unwrap().latency_s
    });
    row("MINLP parametric solve", fmt_time(t));

    let dg = decode_graph(&Qwen3Config::tiny(), 7, None);
    let bufs = nncase_repro::codegen::bufferize(&dg);
    let live = nncase_repro::codegen::Liveness::compute(&dg, &bufs);
    let t = time_median(1, 10, || plan_memory(&bufs, &live, PlannerKind::FirstFit).arena_bytes);
    row("memory planning (tiny decode, first-fit)", fmt_time(t));

    println!("\n== NTT kernels (L3 runtime) ==");
    let mut rng = Rng::new(1);
    for n in [128usize, 256, 512] {
        let a = Tensor::randn(&[n, n], &mut rng, 1.0);
        let b = Tensor::randn(&[n, n], &mut rng, 1.0);
        let flops = 2.0 * (n * n * n) as f64;
        let tb = time_median(2, 7, || matmul_blocked(&a, &b));
        row(
            &format!("matmul_blocked {n}x{n}x{n}"),
            format!("{} ({:.2} GFLOP/s)", fmt_time(tb), flops / tb / 1e9),
        );
        if n <= 256 {
            let tn = time_median(1, 3, || matmul_naive(&a, &b));
            row(
                &format!("matmul_naive   {n}x{n}x{n}"),
                format!("{} ({:.2} GFLOP/s)", fmt_time(tn), flops / tn / 1e9),
            );
        }
    }
    let (k, n) = (1024usize, 1024usize);
    let x: Vec<f32> = (0..k).map(|_| rng.normal()).collect();
    let w = Tensor::randn(&[k, n], &mut rng, 1.0);
    let mut y = vec![0.0f32; n];
    let t = time_median(3, 11, || gemv(&x, &w, &mut y));
    let bytes = (k * n * 4) as f64;
    row(
        "gemv 1024x1024 (weight stream)",
        format!("{} ({:.2} GB/s)", fmt_time(t), bytes / t / 1e9),
    );

    println!("\n== decode engine (real execution, tiny model) ==");
    let cfg = Qwen3Config::tiny();
    for threads in [1usize, 2, 4] {
        let w = Qwen3Weights::random(&cfg, 42);
        let mut e = Qwen3Engine::new(w, threads, 64);
        // Warm the cache with a short prompt.
        for (i, tok) in [1usize, 2, 3].iter().enumerate() {
            e.decode_step(*tok, i);
        }
        let mut pos = 3usize;
        let t = time_median(2, 9, || {
            let l = e.decode_step(7, pos % 60);
            pos += 1;
            l[0]
        });
        // The engine clamps at the model's partition width (tiny:
        // kv_heads = 2), so report the effective worker count.
        row(
            &format!("decode_step {}T (req {threads})", e.threads),
            format!("{} ({:.1} tok/s)", fmt_time(t), 1.0 / t),
        );
    }
    println!("\nhotpaths OK");
}
