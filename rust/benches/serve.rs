//! Serving benchmark: FCFS (batch 1, dense KV) vs continuous batching
//! (paged KV pool) swept over batch pressure × SPMD worker threads.
//!
//! The decode hot path is memory-bound on the weight stream; FCFS pays
//! it once per sequence per token while the batched engine pays it once
//! per iteration, so continuous batching's decode throughput should
//! scale with concurrency — and, past one core, with workers: the
//! batched step shards GEMM row panels and per-sequence attention across
//! the persistent SPMD workers, so threaded decode must beat
//! single-thread once the batch is wide enough to shard.
//!
//! Asserts (full mode):
//! * continuous (1T) >= 2x FCFS decode throughput at 16 concurrent;
//! * continuous 4T > continuous 1T decode throughput at batch 16
//!   (skipped with a warning when the host has < 4 usable cores —
//!   a 1-core CI container cannot demonstrate a parallel speedup);
//! * memory-pressure scenario (hot pool ~ half the working set):
//!   swap-based preemption through the int8 cold tier beats
//!   recompute-based preemption on decode throughput (recompute pays
//!   for replayed positions inside decode time; swap does not);
//! * weight-quant scenario: group-wise int8 weights (fused
//!   dequant-GEMM, ~¼ of the f32 weight stream) beat f32 decode
//!   throughput at batch 1 and batch 16;
//! * prefill scenario (long prompts, prompt_len >= 512): chunked
//!   prefill (`prefill_chunk = 64`) beats chunk-1 TTFT — prompt
//!   ingestion as tall GEMMs instead of batch-of-one steps — with
//!   token-identical outputs;
//! * autotune scenario (always on): a planner-derived
//!   `ContinuousConfig::autotuned` serve is token-identical to the FCFS
//!   oracle, and the chosen `ServePlan` hash is recorded so the
//!   regression tracker keys plan changes as new series;
//! * spec scenario (always on): self-drafting speculative decoding is
//!   token-identical to spec-off on both a lookup-friendly (repetitive
//!   prompt) mix and a random mix — hard asserts — and the perf claims
//!   are warn-gated in *both* modes (spec throughput is acceptance-rate
//!   dependent, too workload-sensitive to gate CI on): spec-on should
//!   beat spec-off decode tok/s on the lookup-friendly mix, and should
//!   cost <= 2% on the random mix where drafts mostly miss.
//!
//! Env knobs (the CI bench-smoke job sets both):
//! * `PALLAS_BENCH_QUICK=1` — reduced workload for a fast smoke signal;
//!   every perf gate (see `gate`) becomes a warning (short quick-mode
//!   runs on shared runners are too noisy to gate CI on).
//! * `PALLAS_BENCH_JSON=path` — write the sweep as a JSON report.
//!
//! Args: `--weight-quant f32|int8|int4` stores the *sweep* scenarios'
//! weight plane in that format; `--prefill-chunk N` runs the sweep
//! scenarios with chunked prefill; `--autotune` replaces the sweep's
//! hand-picked continuous configs with planner-derived ones (explicit
//! thread/chunk knobs still override, mirroring the CLI); `--shards N`
//! pins the shard scenario to one worker-group count instead of the
//! {1, 2, 4} sweep — CI runs the quick bench again with int8 weights,
//! with `--prefill-chunk 64`, with `--autotune`, and with `--spec-k 4`,
//! so the FCFS-vs-continuous token-identity assert and the regression
//! tracker cover the fused dequant-GEMM path, the span-packed step
//! path, the serve-time planner, and the speculative verify path;
//! `--spec-k N` sets the spec scenario's draft depth (default 4).
//!
//! Run: `cargo bench --bench serve [-- --weight-quant int8]
//! [-- --prefill-chunk 64] [-- --autotune] [-- --shards 2]
//! [-- --spec-k 4]`

mod bench_util;

use std::fmt::Write as _;

use bench_util::row;
use nncase_repro::coordinator::{
    synthetic_workload, Coordinator, Qwen3Engine, Request, ServeOptions,
};
use nncase_repro::cost::MachineSpec;
use nncase_repro::model::{Qwen3Config, Qwen3Weights};
use nncase_repro::ntt::WeightQuant;
use nncase_repro::serving::{ContinuousConfig, TierConfig};

struct Sample {
    /// Scenario the sample belongs to: "sweep" (FCFS-vs-continuous),
    /// "pressure-recompute" / "pressure-swap" (the tiered scenario),
    /// "wquant" (f32-vs-int8 weight storage), "prefill" (long-prompt
    /// chunked-vs-chunk-1 TTFT), or "autotune" (planner-derived config
    /// vs the FCFS oracle).
    mode: &'static str,
    /// `ServePlan` hash of the run (`{:016x}`), empty when the config
    /// was hand-picked rather than planner-derived. The regression
    /// tracker keys on it, so a plan change starts a new series instead
    /// of reading as a same-config regression.
    plan: String,
    /// Worker shard groups of the run (1 = unsharded).
    shards: usize,
    /// Weight-plane storage of the run ("f32" / "int8" / "int4").
    weight_quant: &'static str,
    /// Model weight footprint in that format, bytes.
    weight_bytes: u64,
    /// Prefill chunk of the run (1 = the one-token-per-slot seed).
    prefill_chunk: usize,
    /// Speculative-decoding depth of the run (0 = off). Part of the
    /// regression-tracker key: a spec-on series is a different decode
    /// GEMM shape than spec-off, not a same-config regression.
    spec_k: usize,
    pressure: usize,
    threads: usize,
    decode_tok_s: f64,
    /// Prompt positions per second (0.0 where the scenario's prompts
    /// are too short for the number to mean anything).
    prefill_tok_s: f64,
    /// TTFT p50 seconds (the prefill scenario's gating metric).
    ttft_p50_s: f64,
    wall_s: f64,
    speedup_vs_fcfs: f64,
    /// The run's full machine-readable report
    /// (`ServeReport::to_json()`, the `serve_report.v1` schema) nested
    /// verbatim — one source of truth for every metric; the flat keys
    /// above stay for `tools/bench_compare.py` backward compatibility
    /// with committed pre-v1 reports.
    report: String,
}

fn json_report(samples: &[Sample], quick: bool) -> String {
    let mut out = String::from("{\n  \"bench\": \"serve\",\n");
    let _ = writeln!(out, "  \"quick\": {quick},");
    out.push_str("  \"samples\": [\n");
    for (i, s) in samples.iter().enumerate() {
        let _ = write!(
            out,
            "    {{\"mode\": \"{}\", \"plan\": \"{}\", \"shards\": {}, \
             \"weight_quant\": \"{}\", \
             \"weight_bytes\": {}, \
             \"prefill_chunk\": {}, \"spec_k\": {}, \"pressure\": {}, \"threads\": {}, \
             \"decode_tok_s\": {:.3}, \"prefill_tok_s\": {:.3}, \"ttft_p50_s\": {:.6}, \
             \"wall_s\": {:.4}, \"speedup_vs_fcfs\": {:.3}, \"report\": {}}}",
            s.mode,
            s.plan,
            s.shards,
            s.weight_quant,
            s.weight_bytes,
            s.prefill_chunk,
            s.spec_k,
            s.pressure,
            s.threads,
            s.decode_tok_s,
            s.prefill_tok_s,
            s.ttft_p50_s,
            s.wall_s,
            s.speedup_vs_fcfs,
            s.report
        );
        out.push_str(if i + 1 < samples.len() { ",\n" } else { "\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

/// One policy for every perf gate. When `gating` holds and the claim
/// fails, panic; when it fails on a non-gating run (quick mode on a
/// shared runner, or a host without enough cores to show a parallel
/// speedup), print a WARN line instead — short noisy runs report, full
/// runs enforce.
fn gate(gating: bool, name: &str, ok: bool, detail: String) {
    if ok {
        return;
    }
    assert!(!gating, "{name} ({detail})");
    println!("WARN: {name} failed — {detail} — not gating");
}

fn main() {
    let quick = std::env::var("PALLAS_BENCH_QUICK").is_ok();
    // `--weight-quant f32|int8|int4` stores the sweep scenarios' weight
    // plane in that format (the CI bench-smoke job runs the quick bench
    // once more with int8).
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sweep_wq = args
        .iter()
        .position(|a| a == "--weight-quant")
        .and_then(|i| args.get(i + 1))
        .map(|q| WeightQuant::parse(q).unwrap_or_else(|| panic!("bad --weight-quant {q:?}")))
        .unwrap_or(WeightQuant::F32);
    // `--prefill-chunk N` runs the sweep scenarios with span-packed
    // chunked prefill (the token-identity assert then covers the
    // multi-token step path end to end).
    let chunk_flag: Option<usize> = args
        .iter()
        .position(|a| a == "--prefill-chunk")
        .and_then(|i| args.get(i + 1))
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --prefill-chunk {v:?}")));
    let sweep_chunk: usize = chunk_flag.unwrap_or(1);
    // `--autotune` swaps the sweep's hand-picked continuous configs for
    // planner-derived ones; the thread axis and an explicit
    // --prefill-chunk still override the plan's knobs (mirroring the
    // CLI, where explicit flags win over the planner).
    let autotune = args.iter().any(|a| a == "--autotune");
    // `--spec-k N` sets the spec scenario's self-drafting depth (the
    // scenario always runs; the flag only repoints the draft depth so
    // CI can key a separate regression series per depth).
    let spec_flag: usize = args
        .iter()
        .position(|a| a == "--spec-k")
        .and_then(|i| args.get(i + 1))
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --spec-k {v:?}")))
        .unwrap_or(4);
    let machine = MachineSpec::ryzen_5900x();
    let cfg = Qwen3Config::tiny().with_weight_quant(sweep_wq);
    // Quick mode: fewer generated tokens and pressures — a smoke signal
    // for CI, not a measurement.
    let (prompt_len, max_new) = if quick { (4usize, 10usize) } else { (8, 32) };
    let pressures: &[usize] = if quick { &[1, 16] } else { &[1, 4, 16] };
    let thread_counts = [1usize, 4];
    println!(
        "== serving: FCFS vs continuous batching x threads ({}, {}+{} tokens/request, \
         weights {}{}) ==",
        cfg.name,
        prompt_len,
        max_new,
        sweep_wq.name(),
        if quick { ", quick" } else { "" }
    );

    let mut samples = Vec::new();
    let mut speedup_at_16 = 0.0f64;
    let mut tok_s_16 = [0.0f64; 2]; // [1T, 4T] continuous at pressure 16
    for &pressure in pressures {
        let reqs = synthetic_workload(pressure, prompt_len, max_new, cfg.vocab);

        let mut fcfs = Coordinator::new(Qwen3Engine::new(
            Qwen3Weights::random(&cfg, 42),
            1,
            prompt_len + max_new + 1,
        ));
        let fcfs_rep = fcfs.serve(&reqs, &ServeOptions::fcfs());

        for (ti, &threads) in thread_counts.iter().enumerate() {
            let mut cont = Coordinator::new(Qwen3Engine::new(
                Qwen3Weights::random(&cfg, 42),
                1,
                prompt_len + max_new + 1,
            ));
            let mut opts = if autotune {
                ServeOptions::autotuned(pressure).machine(machine.clone())
            } else {
                ServeOptions::continuous(
                    ContinuousConfig::builder()
                        .block_size(16)
                        .num_blocks(4 * pressure + 8)
                        .max_batch(pressure)
                        .prefill_chunk(sweep_chunk)
                        .build(),
                )
            };
            opts = opts.threads(threads);
            if let Some(chunk) = chunk_flag {
                opts = opts.prefill_chunk(chunk);
            }
            let cont_rep = cont.serve(&reqs, &opts);
            let sample_plan = cont_rep
                .plan
                .as_ref()
                .map(|p| format!("{:016x}", p.plan_hash()))
                .unwrap_or_default();
            let sample_chunk = chunk_flag.unwrap_or_else(|| {
                if autotune {
                    cont_rep.plan.as_ref().map(|p| p.prefill_chunk).unwrap_or(1)
                } else {
                    sweep_chunk
                }
            });

            assert_eq!(
                fcfs_rep.outputs, cont_rep.outputs,
                "continuous batching ({threads}T) must be token-identical to the FCFS oracle"
            );

            let speedup = if fcfs_rep.decode_tokens_per_s > 0.0 {
                cont_rep.decode_tokens_per_s / fcfs_rep.decode_tokens_per_s
            } else {
                0.0
            };
            if pressure == 16 {
                tok_s_16[ti] = cont_rep.decode_tokens_per_s;
                if threads == 1 {
                    speedup_at_16 = speedup;
                }
            }
            row(
                &format!("batch {pressure:>2} x {}T", cont_rep.threads),
                format!(
                    "fcfs {:>8.2} tok/s | continuous {:>8.2} tok/s | {:>5.2}x | \
                     wall {:.2}s -> {:.2}s",
                    fcfs_rep.decode_tokens_per_s,
                    cont_rep.decode_tokens_per_s,
                    speedup,
                    fcfs_rep.wall_s,
                    cont_rep.wall_s,
                ),
            );
            if let Some(m) = &cont_rep.serving {
                row("  continuous metrics", m.render());
            }
            samples.push(Sample {
                mode: "sweep",
                plan: sample_plan,
                shards: 1,
                weight_quant: sweep_wq.name(),
                weight_bytes: cfg.weight_bytes(),
                prefill_chunk: sample_chunk,
                spec_k: 0,
                pressure,
                threads: cont_rep.threads,
                decode_tok_s: cont_rep.decode_tokens_per_s,
                prefill_tok_s: cont_rep.prefill_tok_s,
                ttft_p50_s: cont_rep.ttft.percentile(50.0),
                wall_s: cont_rep.wall_s,
                speedup_vs_fcfs: speedup,
                report: cont_rep.to_json(),
            });
        }
    }

    // == Memory-pressure scenario: swap-based vs recompute-based
    // preemption, hot pool sized to ~half the working set. ==
    // 8 concurrent requests over small (4-position) blocks so even the
    // quick workload spans several blocks per sequence; the pool gets
    // half the peak working set, so requests are preempted repeatedly.
    // Recompute replays already-sampled positions (charged to decode
    // time, producing nothing new); swap spills/fetches the int8 cold
    // tier and resumes in place.
    let pressure = 8usize;
    let pressure_bs = 4usize;
    let reqs = synthetic_workload(pressure, prompt_len, max_new, cfg.vocab);
    let working_set = pressure * (prompt_len + max_new + 1).div_ceil(pressure_bs);
    let pool = working_set / 2 + 1;
    let run_pressure = |tiering: Option<TierConfig>| {
        let mut c = Coordinator::new(Qwen3Engine::new(
            Qwen3Weights::random(&cfg, 42),
            1,
            prompt_len + max_new + 1,
        ));
        let mut ccfg = ContinuousConfig::builder()
            .block_size(pressure_bs)
            .num_blocks(pool)
            .max_batch(pressure)
            .build();
        ccfg.tiering = tiering;
        c.serve(&reqs, &ServeOptions::continuous(ccfg))
    };
    let recompute_rep = run_pressure(None);
    let swap_rep = run_pressure(Some(TierConfig::new(working_set + 4)));
    let rm = recompute_rep.serving.as_ref().expect("metrics");
    let sm = swap_rep.serving.as_ref().expect("metrics");
    assert!(rm.recompute_preemptions > 0, "the half-size pool must force recompute");
    assert!(sm.swap_preemptions > 0 && sm.recompute_preemptions == 0, "tiered run must swap");
    assert_eq!(
        recompute_rep.generated_tokens, swap_rep.generated_tokens,
        "both preemption modes must finish the full workload"
    );
    let swap_speedup = if recompute_rep.decode_tokens_per_s > 0.0 {
        swap_rep.decode_tokens_per_s / recompute_rep.decode_tokens_per_s
    } else {
        0.0
    };
    row(
        &format!("pressure pool={pool}/{working_set}"),
        format!(
            "recompute {:>8.2} tok/s (replay {}) | swap {:>8.2} tok/s ({}) | {:>5.2}x",
            recompute_rep.decode_tokens_per_s,
            rm.replay_steps,
            swap_rep.decode_tokens_per_s,
            swap_rep.tier.as_deref().unwrap_or("-"),
            swap_speedup,
        ),
    );
    row("  swap metrics", sm.render());
    for (mode, rep) in [("pressure-recompute", &recompute_rep), ("pressure-swap", &swap_rep)] {
        samples.push(Sample {
            mode,
            plan: String::new(),
            shards: 1,
            weight_quant: sweep_wq.name(),
            weight_bytes: cfg.weight_bytes(),
            prefill_chunk: 1,
            spec_k: 0,
            pressure,
            threads: 1,
            decode_tok_s: rep.decode_tokens_per_s,
            prefill_tok_s: rep.prefill_tok_s,
            ttft_p50_s: rep.ttft.percentile(50.0),
            wall_s: rep.wall_s,
            speedup_vs_fcfs: 0.0,
            report: rep.to_json(),
        });
    }
    gate(
        !quick,
        "swap-based preemption must beat recompute on decode throughput under memory pressure",
        swap_speedup > 1.0,
        format!(
            "swap {:.2} vs recompute {:.2} tok/s, {swap_speedup:.2}x",
            swap_rep.decode_tokens_per_s, recompute_rep.decode_tokens_per_s,
        ),
    );

    // == Weight-quant scenario: f32 vs group-wise int8 weight storage,
    // continuous decode at batch 1 and batch 16. ==
    // Decode streams the full weight plane every iteration; int8 codes
    // cut that stream to ~¼ (the fused dequant-GEMM kernels expand one
    // 2 KB panel group at a time in L1), so int8 decode throughput must
    // beat f32 at both batch widths on a memory-bound host. Always run
    // from the base config so the comparison is canonical even when
    // `--weight-quant` re-pointed the sweep above.
    let mut wq_tok_s = Vec::new(); // (pressure, f32 tok/s, int8 tok/s)
    for &pressure in &[1usize, 16] {
        let reqs = synthetic_workload(pressure, prompt_len, max_new, cfg.vocab);
        let mut per_mode = [0.0f64; 2];
        for (mi, mode) in [WeightQuant::F32, WeightQuant::Int8].into_iter().enumerate() {
            let qcfg = Qwen3Config::tiny().with_weight_quant(mode);
            let mut c = Coordinator::new(Qwen3Engine::new(
                Qwen3Weights::random(&qcfg, 42),
                1,
                prompt_len + max_new + 1,
            ));
            let ccfg = ContinuousConfig::builder()
                .block_size(16)
                .num_blocks(4 * pressure + 8)
                .max_batch(pressure)
                .build();
            let rep = c.serve(&reqs, &ServeOptions::continuous(ccfg));
            per_mode[mi] = rep.decode_tokens_per_s;
            samples.push(Sample {
                mode: "wquant",
                plan: String::new(),
                shards: 1,
                weight_quant: mode.name(),
                weight_bytes: qcfg.weight_bytes(),
                prefill_chunk: 1,
                spec_k: 0,
                pressure,
                threads: 1,
                decode_tok_s: rep.decode_tokens_per_s,
                prefill_tok_s: rep.prefill_tok_s,
                ttft_p50_s: rep.ttft.percentile(50.0),
                wall_s: rep.wall_s,
                speedup_vs_fcfs: 0.0,
                report: rep.to_json(),
            });
        }
        let ratio = if per_mode[0] > 0.0 { per_mode[1] / per_mode[0] } else { 0.0 };
        row(
            &format!("wquant batch {pressure:>2}"),
            format!(
                "f32 {:>8.2} tok/s | int8 {:>8.2} tok/s | {ratio:>5.2}x",
                per_mode[0], per_mode[1]
            ),
        );
        wq_tok_s.push((pressure, per_mode[0], per_mode[1]));
    }
    for &(pressure, f32_tok_s, i8_tok_s) in &wq_tok_s {
        gate(
            !quick,
            &format!("int8-weight decode must beat f32 at batch {pressure}"),
            i8_tok_s > f32_tok_s,
            format!("int8 {i8_tok_s:.2} vs f32 {f32_tok_s:.2} tok/s"),
        );
    }

    // == Prefill scenario: long prompts, chunked vs chunk-1 TTFT. ==
    // At prompt_len 512, chunk-1 prefill is 512 batch-of-few
    // GEMV-shaped iterations per prompt (memory-bound on the weight
    // stream); chunk 64 packs the same positions into 64-row spans —
    // tall GEMMs against the compute roof (`cost::prefill_flops_s`) —
    // so time-to-first-token must drop while outputs stay
    // token-identical.
    let prefill_len = 512usize;
    let prefill_new = 4usize;
    let prefill_reqs_n = if quick { 2usize } else { 4 };
    let prefill_reqs = synthetic_workload(prefill_reqs_n, prefill_len, prefill_new, cfg.vocab);
    let prefill_blocks =
        prefill_reqs_n * (prefill_len + prefill_new + 1).div_ceil(16) + 8;
    let run_prefill = |chunk: usize| {
        let mut c = Coordinator::new(Qwen3Engine::new(
            Qwen3Weights::random(&cfg, 42),
            1,
            prefill_len + prefill_new + 1,
        ));
        let ccfg = ContinuousConfig::builder()
            .block_size(16)
            .num_blocks(prefill_blocks)
            .max_batch(prefill_reqs_n)
            .prefill_chunk(chunk)
            .build();
        c.serve(&prefill_reqs, &ServeOptions::continuous(ccfg))
    };
    let chunk1_rep = run_prefill(1);
    let chunked_rep = run_prefill(64);
    assert_eq!(
        chunk1_rep.outputs, chunked_rep.outputs,
        "chunked prefill must be token-identical to chunk 1"
    );
    let ttft1 = chunk1_rep.ttft.percentile(50.0);
    let ttft64 = chunked_rep.ttft.percentile(50.0);
    let ttft_speedup = if ttft64 > 0.0 { ttft1 / ttft64 } else { 0.0 };
    row(
        &format!("prefill len={prefill_len} x{prefill_reqs_n}"),
        format!(
            "chunk 1: ttft p50 {:>8.2}ms, {:>8.2} tok/s | chunk 64: ttft p50 {:>8.2}ms, \
             {:>8.2} tok/s | {ttft_speedup:>5.2}x ttft",
            ttft1 * 1e3,
            chunk1_rep.prefill_tok_s,
            ttft64 * 1e3,
            chunked_rep.prefill_tok_s,
        ),
    );
    for (chunk, rep) in [(1usize, &chunk1_rep), (64, &chunked_rep)] {
        samples.push(Sample {
            mode: "prefill",
            plan: String::new(),
            shards: 1,
            weight_quant: sweep_wq.name(),
            weight_bytes: cfg.weight_bytes(),
            prefill_chunk: chunk,
            spec_k: 0,
            pressure: prefill_reqs_n,
            threads: 1,
            decode_tok_s: rep.decode_tokens_per_s,
            prefill_tok_s: rep.prefill_tok_s,
            ttft_p50_s: rep.ttft.percentile(50.0),
            wall_s: rep.wall_s,
            speedup_vs_fcfs: 0.0,
            report: rep.to_json(),
        });
    }
    gate(
        !quick,
        &format!("chunked prefill must beat chunk-1 TTFT at prompt_len {prefill_len}"),
        ttft64 < ttft1,
        format!("chunk 64 {:.2}ms vs chunk 1 {:.2}ms", ttft64 * 1e3, ttft1 * 1e3),
    );

    // == Autotune scenario: planner-derived config vs the FCFS oracle. ==
    // `ContinuousConfig::autotuned` derives chunk / budget / threads /
    // panel granularity / pool sizing from the serve-time planner
    // (schedule::tile candidates scored by the cost rooflines). The
    // plan is a pure perf annotation, so the run must stay
    // token-identical to FCFS; the plan hash goes into the sample so
    // the regression tracker treats a plan change as a new series.
    let at_pressure = 8usize;
    let at_reqs = synthetic_workload(at_pressure, prompt_len, max_new, cfg.vocab);
    let mut at_fcfs = Coordinator::new(Qwen3Engine::new(
        Qwen3Weights::random(&cfg, 42),
        1,
        prompt_len + max_new + 1,
    ));
    let at_fcfs_rep = at_fcfs.serve(&at_reqs, &ServeOptions::fcfs());
    let mut at_cont = Coordinator::new(Qwen3Engine::new(
        Qwen3Weights::random(&cfg, 42),
        1,
        prompt_len + max_new + 1,
    ));
    let at_rep = at_cont
        .serve(&at_reqs, &ServeOptions::autotuned(at_pressure).machine(machine.clone()));
    let at_plan = at_rep.plan.clone().expect("an autotuned run records its plan");
    assert_eq!(
        at_fcfs_rep.outputs, at_rep.outputs,
        "the autotuned serve must be token-identical to the FCFS oracle \
         (plans are semantics-free)"
    );
    row(
        &format!("autotune batch {at_pressure}"),
        format!(
            "fcfs {:>8.2} tok/s | autotuned {:>8.2} tok/s | plan {}",
            at_fcfs_rep.decode_tokens_per_s,
            at_rep.decode_tokens_per_s,
            at_plan.render(),
        ),
    );
    samples.push(Sample {
        mode: "autotune",
        plan: format!("{:016x}", at_plan.plan_hash()),
        shards: 1,
        weight_quant: sweep_wq.name(),
        weight_bytes: cfg.weight_bytes(),
        prefill_chunk: at_plan.prefill_chunk,
        spec_k: 0,
        pressure: at_pressure,
        threads: at_rep.threads,
        decode_tok_s: at_rep.decode_tokens_per_s,
        prefill_tok_s: at_rep.prefill_tok_s,
        ttft_p50_s: at_rep.ttft.percentile(50.0),
        wall_s: at_rep.wall_s,
        speedup_vs_fcfs: if at_fcfs_rep.decode_tokens_per_s > 0.0 {
            at_rep.decode_tokens_per_s / at_fcfs_rep.decode_tokens_per_s
        } else {
            0.0
        },
        report: at_rep.to_json(),
    });

    // == Shard scenario: dist-sharded continuous decode vs unsharded. ==
    // `--shards N` pins one worker-group count; default sweeps {1, 2, 4}.
    // The projection GEMMs are partitioned across the groups with the
    // split-vs-broadcast layout chosen per weight matrix by the dist
    // cost model; the cross-shard combine is disjoint column placement
    // (never a floating-point reduction), so every count must stay
    // token-identical — asserted against the count-1 run — while each
    // group streams only its share of the sharded weight columns.
    let shard_flag: Option<usize> = args
        .iter()
        .position(|a| a == "--shards")
        .and_then(|i| args.get(i + 1))
        .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --shards {v:?}")));
    let shard_counts: Vec<usize> = match shard_flag {
        Some(s) => vec![s],
        None => vec![1, 2, 4],
    };
    let shard_pressure = 8usize;
    let shard_reqs = synthetic_workload(shard_pressure, prompt_len, max_new, cfg.vocab);
    let shard_machine = MachineSpec::test_numa();
    let mut shard_base: Option<Vec<(u64, Vec<usize>)>> = None;
    for &shards in &shard_counts {
        let mut c = Coordinator::new(Qwen3Engine::new(
            Qwen3Weights::random(&cfg, 42),
            1,
            prompt_len + max_new + 1,
        ));
        let ccfg = ContinuousConfig::builder()
            .block_size(16)
            .num_blocks(4 * shard_pressure + 8)
            .max_batch(shard_pressure)
            .build();
        let opts = ServeOptions::continuous(ccfg)
            .threads(1)
            .shards(shards)
            .machine(shard_machine.clone());
        let rep = c.serve(&shard_reqs, &opts);
        match &shard_base {
            Some(want) => assert_eq!(
                want, &rep.outputs,
                "sharded serving ({shards} groups) must be token-identical to unsharded"
            ),
            None => shard_base = Some(rep.outputs.clone()),
        }
        row(
            &format!("shards {shards} x 1T"),
            format!(
                "{:>8.2} tok/s | sbp [{}]",
                rep.decode_tokens_per_s,
                rep.sbp_sig.as_deref().unwrap_or("-"),
            ),
        );
        samples.push(Sample {
            mode: "shard",
            plan: String::new(),
            shards,
            weight_quant: sweep_wq.name(),
            weight_bytes: cfg.weight_bytes(),
            prefill_chunk: 1,
            spec_k: 0,
            pressure: shard_pressure,
            threads: 1,
            decode_tok_s: rep.decode_tokens_per_s,
            prefill_tok_s: rep.prefill_tok_s,
            ttft_p50_s: rep.ttft.percentile(50.0),
            wall_s: rep.wall_s,
            speedup_vs_fcfs: 0.0,
            report: rep.to_json(),
        });
    }

    // == Chaos scenario: mid-run worker panic under full batch pressure. ==
    // A deterministic failpoint kills a worker at decode iteration 10;
    // the epoch-restart recovery audits the pool, rolls in-flight
    // sequences back to committed KV and replays. Token identity to the
    // calm run and a clean (zero-leak) audit are hard asserts — they are
    // correctness, not perf. The recovered run's throughput is reported
    // warn-only: one epoch restart re-pays in-flight work, so a tax is
    // expected; the number here sizes it.
    let chaos_pressure = 16usize;
    let chaos_reqs = synthetic_workload(chaos_pressure, prompt_len, max_new, cfg.vocab);
    let run_chaos = |faults: Option<nncase_repro::serving::FaultPlan>| {
        let mut c = Coordinator::new(Qwen3Engine::new(
            Qwen3Weights::random(&cfg, 42),
            1,
            prompt_len + max_new + 1,
        ));
        let ccfg = ContinuousConfig::builder()
            .block_size(16)
            .num_blocks(4 * chaos_pressure + 8)
            .max_batch(chaos_pressure)
            .build();
        let mut opts = ServeOptions::continuous(ccfg).threads(2);
        if let Some(plan) = faults {
            opts = opts.faults(plan);
        }
        c.serve(&chaos_reqs, &opts)
    };
    let calm_rep = run_chaos(None);
    let chaos_plan = nncase_repro::serving::FaultPlan::new().panic_at(
        nncase_repro::obs::Code::Attn,
        10,
        None,
    );
    let chaos_rep = run_chaos(Some(chaos_plan));
    assert_eq!(
        calm_rep.outputs, chaos_rep.outputs,
        "panic recovery must be token-identical to the calm run"
    );
    let chaos_faults = chaos_rep.faults.as_ref().expect("fault ledger");
    assert_eq!(chaos_faults.injected, 1, "the failpoint must actually fire");
    assert_eq!(chaos_faults.recovered, 1, "one epoch restart must absorb it");
    assert_eq!(
        chaos_rep.serving.as_ref().unwrap().fault_leaked_blocks,
        0,
        "the recovery audit must find no leaked blocks"
    );
    let chaos_tax = if calm_rep.decode_tokens_per_s > 0.0 {
        chaos_rep.decode_tokens_per_s / calm_rep.decode_tokens_per_s
    } else {
        0.0
    };
    row(
        &format!("chaos batch {chaos_pressure} x 2T"),
        format!(
            "calm {:>8.2} tok/s | recovered {:>8.2} tok/s | {chaos_tax:>5.2}x \
             (requeued {})",
            calm_rep.decode_tokens_per_s,
            chaos_rep.decode_tokens_per_s,
            chaos_faults.requeued,
        ),
    );
    for (mode, rep) in [("chaos-calm", &calm_rep), ("chaos-faulted", &chaos_rep)] {
        samples.push(Sample {
            mode,
            plan: String::new(),
            shards: 1,
            weight_quant: sweep_wq.name(),
            weight_bytes: cfg.weight_bytes(),
            prefill_chunk: 1,
            spec_k: 0,
            pressure: chaos_pressure,
            threads: 2,
            decode_tok_s: rep.decode_tokens_per_s,
            prefill_tok_s: rep.prefill_tok_s,
            ttft_p50_s: rep.ttft.percentile(50.0),
            wall_s: rep.wall_s,
            speedup_vs_fcfs: 0.0,
            report: rep.to_json(),
        });
    }
    gate(
        false, // never gating: one restart's replay tax is workload-dependent
        "recovered throughput should stay within 2x of the calm run",
        chaos_tax > 0.5,
        format!(
            "recovered {:.2} vs calm {:.2} tok/s",
            chaos_rep.decode_tokens_per_s, calm_rep.decode_tokens_per_s,
        ),
    );

    // == Spec scenario: self-drafting speculative decoding vs spec-off. ==
    // Two workload shapes, each served twice (spec off / spec on):
    // * "spec-lookup" — prompts cycle a short motif, so decode keeps
    //   re-entering already-seen n-gram contexts and the prompt-lookup
    //   drafter lands drafts; accepted drafts collapse decode
    //   iterations into multi-row verify spans, so decode tok/s should
    //   rise;
    // * "spec-random" — the sweep's random prompts, where drafts mostly
    //   miss; this shape prices the verify-row overhead, which should
    //   stay within 2% of spec-off decode throughput.
    // Token identity to the spec-off run is a hard assert in both
    // shapes: greedy acceptance emits only the model's own argmaxes, so
    // speculation is semantics-free by construction. The perf claims
    // are warn-only even in full mode — acceptance rate (and with it
    // throughput) depends on how repetitive the *generated* stream is,
    // which a tiny random-weight model does not promise — the numbers
    // here size the win/tax rather than gate it.
    let spec_pressure = 8usize;
    let spec_new = if quick { 12usize } else { 32 };
    let spec_prompt_len = 9usize;
    let lookup_reqs: Vec<Request> = (0..spec_pressure)
        .map(|i| {
            let motif = [7usize, 1031, 299];
            Request {
                id: i as u64,
                prompt: (0..spec_prompt_len)
                    .map(|p| (motif[p % motif.len()] + 97 * i) % cfg.vocab)
                    .collect(),
                max_new_tokens: spec_new,
            }
        })
        .collect();
    let random_reqs = synthetic_workload(spec_pressure, prompt_len, spec_new, cfg.vocab);
    let spec_ctx = spec_prompt_len.max(prompt_len) + spec_new + 1;
    let run_spec = |reqs: &[Request], k: usize| {
        let mut c =
            Coordinator::new(Qwen3Engine::new(Qwen3Weights::random(&cfg, 42), 1, spec_ctx));
        let ccfg = ContinuousConfig::builder()
            .block_size(16)
            .num_blocks(4 * spec_pressure + 8)
            .max_batch(spec_pressure)
            .build();
        c.serve(reqs, &ServeOptions::continuous(ccfg).spec_k(k))
    };
    let mut spec_tok_s = Vec::new(); // (shape, off tok/s, on tok/s)
    for (shape, reqs) in [("spec-lookup", &lookup_reqs), ("spec-random", &random_reqs)] {
        let off_rep = run_spec(reqs, 0);
        let on_rep = run_spec(reqs, spec_flag);
        assert_eq!(
            off_rep.outputs, on_rep.outputs,
            "{shape}: speculative decoding (k={spec_flag}) must be token-identical to spec-off"
        );
        assert!(off_rep.spec.is_none(), "a spec-off run must not report a spec summary");
        let sm = on_rep.spec.as_ref().expect("a spec-on run reports its spec summary");
        let spec_speedup = if off_rep.decode_tokens_per_s > 0.0 {
            on_rep.decode_tokens_per_s / off_rep.decode_tokens_per_s
        } else {
            0.0
        };
        row(
            &format!("{shape} k={spec_flag}"),
            format!(
                "off {:>8.2} tok/s | on {:>8.2} tok/s | {spec_speedup:>5.2}x | \
                 accept {:>5.1}% | {:.2} tok/step",
                off_rep.decode_tokens_per_s,
                on_rep.decode_tokens_per_s,
                100.0 * sm.accept_rate,
                sm.accepted_tokens_per_step,
            ),
        );
        for (k, rep) in [(0usize, &off_rep), (spec_flag, &on_rep)] {
            samples.push(Sample {
                mode: shape,
                plan: String::new(),
                shards: 1,
                weight_quant: sweep_wq.name(),
                weight_bytes: cfg.weight_bytes(),
                prefill_chunk: 1,
                spec_k: k,
                pressure: spec_pressure,
                threads: 1,
                decode_tok_s: rep.decode_tokens_per_s,
                prefill_tok_s: rep.prefill_tok_s,
                ttft_p50_s: rep.ttft.percentile(50.0),
                wall_s: rep.wall_s,
                speedup_vs_fcfs: 0.0,
                report: rep.to_json(),
            });
        }
        if shape == "spec-lookup" {
            gate(
                false, // never gating: acceptance depends on the generated stream
                "spec-on should accept more than one token per decode step on the lookup mix",
                sm.accepted_tokens_per_step > 1.0,
                format!(
                    "{:.2} tok/step (accept {:.1}%, {} drafted)",
                    sm.accepted_tokens_per_step,
                    100.0 * sm.accept_rate,
                    sm.drafted,
                ),
            );
        }
        spec_tok_s.push((shape, off_rep.decode_tokens_per_s, on_rep.decode_tokens_per_s));
    }
    for &(shape, off, on) in &spec_tok_s {
        let lookup = shape == "spec-lookup";
        let claim = if lookup {
            "spec-on should beat spec-off decode throughput on the lookup-friendly mix"
        } else {
            "spec-on overhead on the random mix should stay within 2% of spec-off"
        };
        let ok = if lookup { on > off } else { on >= 0.98 * off };
        gate(
            false, // never gating: both sides ride the acceptance rate
            claim,
            ok,
            format!("on {on:.2} vs off {off:.2} tok/s"),
        );
    }

    // == Per-scenario noise summary. ==
    // How spread out each scenario's decode throughput samples are —
    // the number to check before trusting any single gate ratio above,
    // and the context bench_compare.py lacks when it flags a delta.
    {
        let mut modes: Vec<&'static str> = Vec::new();
        for s in &samples {
            if !modes.contains(&s.mode) {
                modes.push(s.mode);
            }
        }
        println!("\nnoise summary (decode tok/s per scenario):");
        for mode in modes {
            let mut st = nncase_repro::util::Stats::default();
            for s in samples.iter().filter(|s| s.mode == mode) {
                st.push(s.decode_tok_s);
            }
            row(
                mode,
                format!(
                    "n={:>2} mean {:>9.2} p99 {:>9.2} stddev {:>8.2} ({:>5.1}% of mean)",
                    st.len(),
                    st.mean(),
                    st.p99(),
                    st.stddev(),
                    if st.mean() > 0.0 { 100.0 * st.stddev() / st.mean() } else { 0.0 },
                ),
            );
        }
    }

    if let Ok(path) = std::env::var("PALLAS_BENCH_JSON") {
        std::fs::write(&path, json_report(&samples, quick)).expect("write bench JSON");
        println!("json report -> {path}");
    }

    gate(
        !quick,
        "continuous batching must be >= 2x FCFS decode throughput at 16 concurrent requests",
        speedup_at_16 >= 2.0,
        format!("{speedup_at_16:.2}x"),
    );

    // Threaded decode must beat single-thread at batch 16 — the SPMD
    // partition is only worth shipping if it actually buys throughput.
    // A < 4-core host cannot demonstrate the speedup, so it never gates
    // there regardless of mode.
    let thread_speedup = if tok_s_16[0] > 0.0 { tok_s_16[1] / tok_s_16[0] } else { 0.0 };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    gate(
        !quick && cores >= 4,
        "4T continuous decode must beat 1T at batch 16",
        thread_speedup > 1.0,
        format!(
            "{:.2} vs {:.2} tok/s, {thread_speedup:.2}x ({cores} cores, quick={quick})",
            tok_s_16[1], tok_s_16[0],
        ),
    );
    println!(
        "\nserve OK ({speedup_at_16:.2}x batching at 16 concurrent, \
         {thread_speedup:.2}x from 4 workers)"
    );
}
