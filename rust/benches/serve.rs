//! Serving benchmark: FCFS (batch 1, dense KV) vs continuous batching
//! (paged KV pool) on the synthetic workload at batch pressures
//! {1, 4, 16}.
//!
//! The decode hot path is memory-bound on the weight stream; FCFS pays
//! it once per sequence per token while the batched engine pays it once
//! per iteration, so continuous batching's decode throughput should
//! scale with concurrency until attention (per-sequence) dominates.
//!
//! Run: `cargo bench --bench serve`

mod bench_util;

use bench_util::row;
use nncase_repro::coordinator::{synthetic_workload, Coordinator, Qwen3Engine, ServePolicy};
use nncase_repro::model::{Qwen3Config, Qwen3Weights};
use nncase_repro::serving::ContinuousConfig;

fn main() {
    let cfg = Qwen3Config::tiny();
    let (prompt_len, max_new) = (8usize, 32usize);
    println!(
        "== serving: FCFS vs continuous batching ({}, {}+{} tokens/request) ==",
        cfg.name, prompt_len, max_new
    );

    let mut speedup_at_16 = 0.0f64;
    for pressure in [1usize, 4, 16] {
        let reqs = synthetic_workload(pressure, prompt_len, max_new, cfg.vocab);

        let mut fcfs = Coordinator::new(Qwen3Engine::new(
            Qwen3Weights::random(&cfg, 42),
            1,
            prompt_len + max_new + 1,
        ));
        let fcfs_rep = fcfs.serve(&reqs);

        let mut cont = Coordinator::new(Qwen3Engine::new(
            Qwen3Weights::random(&cfg, 42),
            1,
            prompt_len + max_new + 1,
        ));
        let ccfg = ContinuousConfig {
            block_size: 16,
            num_blocks: 4 * pressure + 8,
            max_batch: pressure,
        };
        let cont_rep = cont.serve_with_policy(&reqs, ServePolicy::Continuous(ccfg));

        assert_eq!(
            fcfs_rep.outputs, cont_rep.outputs,
            "continuous batching must be token-identical to the FCFS oracle"
        );

        let speedup = if fcfs_rep.decode_tokens_per_s > 0.0 {
            cont_rep.decode_tokens_per_s / fcfs_rep.decode_tokens_per_s
        } else {
            0.0
        };
        if pressure == 16 {
            speedup_at_16 = speedup;
        }
        row(
            &format!("batch pressure {pressure:>2}"),
            format!(
                "fcfs {:>8.2} tok/s | continuous {:>8.2} tok/s | {:>5.2}x | wall {:.2}s -> {:.2}s",
                fcfs_rep.decode_tokens_per_s,
                cont_rep.decode_tokens_per_s,
                speedup,
                fcfs_rep.wall_s,
                cont_rep.wall_s,
            ),
        );
        if let Some(m) = &cont_rep.serving {
            row("  continuous metrics", m.render());
        }
    }

    assert!(
        speedup_at_16 >= 2.0,
        "continuous batching must be >= 2x FCFS decode throughput at 16 \
         concurrent requests (got {speedup_at_16:.2}x)"
    );
    println!("\nserve OK ({speedup_at_16:.2}x at 16 concurrent)");
}
