//! Figure 9 regeneration: single-core (1T) decode throughput, all
//! models x all frameworks, on the Roofline simulator, with the paper's
//! reference values and shape checks.
//!
//! Run: `cargo bench --bench fig9`

use nncase_repro::cost::MachineSpec;
use nncase_repro::sim::figures::{fig9_table, render};

fn main() {
    let machine = MachineSpec::ryzen_5900x();
    let rows = fig9_table(&machine);
    println!("{}", render(&rows, "Figure 9 — single-core (1T) token throughput"));

    // Shape assertions from §4.1 (who wins, by roughly what factor).
    let get = |model: &str, fw: &str| {
        rows.iter()
            .find(|r| r.model == model && r.framework == fw)
            .map(|r| r.tokens_per_s)
            .unwrap()
    };
    for model in ["Qwen3-0.6B-f32", "Qwen3-0.6B-f16", "Qwen3-1.7B-f16"] {
        let (l, n, i, m) = (
            get(model, "llama.cpp"),
            get(model, "nncase"),
            get(model, "Intel IPEX"),
            get(model, "MLC LLM"),
        );
        assert!(l > n && n > i && i > 2.0 * m, "{model}: hierarchy violated");
        println!(
            "{model}: llama.cpp/nncase = {:.2} (paper ~1.2), \
             nncase/IPEX = {:.2} (paper ~1.15-1.35)",
            l / n,
            n / i
        );
    }
    let f32t = get("Qwen3-0.6B-f32", "nncase");
    let f16t = get("Qwen3-0.6B-f16", "nncase");
    println!(
        "nncase F16 gain over F32: {:.0}% (paper: 59%)",
        (f16t / f32t - 1.0) * 100.0
    );
    println!("\nfig9 shape checks OK");
}
