#!/usr/bin/env python3
"""Compare two serve-bench JSON reports and warn on decode-throughput
regressions.

Seeds the perf-regression tracker ROADMAP asks for: the CI bench-smoke
job downloads the previous successful run's `serve-bench.json` artifact
and diffs it against the fresh one. Samples are matched on
(mode, pressure, threads); any decode_tok_s drop beyond --warn-pct
emits a GitHub `::warning::` annotation. Exit code is always 0 — quick
bench-smoke runs on shared runners are too noisy to gate merges on, so
this warns and records rather than fails (flip --strict once a few runs
have accumulated and the noise floor is known).
"""

import argparse
import json
import sys
from pathlib import Path


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench-compare: cannot read {path}: {e}")
        return None


def key(sample):
    # Older reports predate the "mode" / "weight_quant" fields; the
    # defaults keep them comparable. Keying on (mode, weight_quant)
    # means an f32 sweep sample is never diffed against an int8 one —
    # the two run different kernels and byte volumes, so collapsing
    # them would report a quant-vs-f32 ratio as a "regression".
    return (sample.get("mode", "sweep"), sample.get("weight_quant", "f32"),
            sample["pressure"], sample["threads"])


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--prev", required=True, help="previous run's serve-bench.json")
    ap.add_argument("--cur", required=True, help="this run's serve-bench.json")
    ap.add_argument("--warn-pct", type=float, default=10.0,
                    help="decode-throughput drop (percent) that triggers a warning")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when a regression is found")
    args = ap.parse_args()

    if not Path(args.prev).exists():
        print(f"bench-compare: no previous report at {args.prev} (first run?) — skipping")
        return 0
    prev, cur = load(args.prev), load(args.cur)
    if prev is None or cur is None:
        return 0
    if prev.get("quick") != cur.get("quick"):
        print("bench-compare: quick-mode mismatch between runs — skipping (not comparable)")
        return 0

    prev_by_key = {key(s): s for s in prev.get("samples", [])}
    regressions = []
    for s in cur.get("samples", []):
        p = prev_by_key.get(key(s))
        if p is None or p["decode_tok_s"] <= 0.0:
            continue
        delta_pct = 100.0 * (s["decode_tok_s"] - p["decode_tok_s"]) / p["decode_tok_s"]
        tag = ""
        if delta_pct < -args.warn_pct:
            tag = "  <-- REGRESSION"
            regressions.append((key(s), delta_pct))
        print(f"  {key(s)}: {p['decode_tok_s']:.2f} -> {s['decode_tok_s']:.2f} tok/s "
              f"({delta_pct:+.1f}%){tag}")

    if regressions:
        for k, pct in regressions:
            print(f"::warning title=decode-throughput regression::"
                  f"{k}: {pct:+.1f}% vs previous run (threshold -{args.warn_pct:.0f}%)")
        if args.strict:
            return 1
    else:
        print(f"bench-compare: no decode-throughput regression beyond {args.warn_pct:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
