#!/usr/bin/env python3
"""Compare two serve-bench JSON reports and warn on throughput
regressions.

Seeds the perf-regression tracker ROADMAP asks for: the CI bench-smoke
job downloads the previous successful run's `serve-bench.json` artifact
and diffs it against the fresh one. Samples are matched on
(mode, plan, shards, weight_quant, prefill_chunk, spec_k, pressure,
threads) — `plan` is the ServePlan hash of autotuned runs (empty for
hand-picked configs), so a planner change starts a new series instead
of reading as a same-config regression; `shards` keys the dist-sharded
scenario's worker-group counts apart (default 1 for pre-shard
reports); `spec_k` keys speculative-decoding depths apart (default 0
for pre-spec reports) — a spec-on run steps a different decode GEMM
shape than spec-off, so diffing them would report a configuration
ratio as a regression. Any drop in the scenario's gating metric
(prefill tok/s for the "prefill" scenario, decode tok/s otherwise)
beyond --warn-pct emits a GitHub `::warning::` annotation. A
per-scenario noise summary (mean/max |delta| across the compared keys)
is printed at the end so the noise floor across runs can be judged
against the threshold. By default exit code is 0 — quick bench-smoke
runs on shared runners are too noisy to gate merges on, so this warns
and records rather than fails. `--strict` gates on every regression;
`--strict-modes sweep,wquant` gates only on regressions in the named
scenarios (flip a scenario in once its noise summaries over a few runs
sit comfortably under the threshold, leave the rest advisory).

Since the serve_report.v1 schema landed, each sample nests the run's
full `ServeReport::to_json()` under "report"; metrics are read from it
when present (see `field`), with the flat sample keys kept as the
fallback for committed pre-v1 artifacts.
"""

import argparse
import json
import sys
from collections import defaultdict
from pathlib import Path


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench-compare: cannot read {path}: {e}")
        return None


def field(sample, name, default=None):
    """Read a metric from a sample, preferring the nested
    `serve_report.v1` object (`sample["report"]`, emitted by the bench
    since the ServeReport::to_json schema landed) and falling back to
    the flat sample keys that committed pre-v1 reports (BENCH_6/7.json)
    carry. Both spell shared keys identically (decode_tok_s,
    prefill_tok_s, threads, shards, weight_quant, ...), so the nested
    object is a strict superset and the fallback is purely for old
    artifacts."""
    rep = sample.get("report")
    if isinstance(rep, dict) and rep.get("schema") == "serve_report.v1" and name in rep:
        # A partial/corrupt nested report (e.g. a truncated artifact)
        # can carry nulls; fall back rather than propagate None into
        # arithmetic downstream.
        if rep[name] is not None:
            return rep[name]
    return sample.get(name, default)


def key(sample):
    # Older reports predate the "mode" / "plan" / "weight_quant" /
    # "prefill_chunk" fields; the defaults keep them comparable. Keying
    # on all of them means an f32 chunk-1 sweep sample is never diffed
    # against an int8 or chunked one — those run different kernels,
    # byte volumes and step shapes, so collapsing them would report a
    # configuration ratio as a "regression". The plan hash does the
    # same for autotuned runs: a deliberate planner change re-keys the
    # series rather than tripping the regression warning. mode / plan /
    # pressure / prefill_chunk / spec_k are bench-scenario identity,
    # which the per-run report does not carry at its top level — those
    # stay flat-only (the nested report spells spec depth under "spec",
    # out of `field`'s flat reach).
    # Every lookup defaults: a hand-edited or truncated artifact with a
    # missing key must degrade to "no matching series" (the sample just
    # won't pair up), never crash the whole comparison.
    return (sample.get("mode", "sweep"), sample.get("plan", ""),
            field(sample, "shards", 1),
            field(sample, "weight_quant", "f32"),
            sample.get("prefill_chunk", 1), sample.get("spec_k", 0),
            sample.get("pressure", 0),
            field(sample, "threads", 1))


def metric(sample):
    """The gating metric of a sample's scenario: the prefill scenario
    generates almost nothing (its decode tok/s is noise), so it is
    tracked on prefill throughput instead."""
    if sample.get("mode", "sweep") == "prefill":
        return "prefill_tok_s", field(sample, "prefill_tok_s", 0.0)
    return "decode_tok_s", field(sample, "decode_tok_s", 0.0)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--prev", required=True, help="previous run's serve-bench.json")
    ap.add_argument("--cur", required=True, help="this run's serve-bench.json")
    ap.add_argument("--warn-pct", type=float, default=10.0,
                    help="throughput drop (percent) that triggers a warning")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when a regression is found")
    ap.add_argument("--strict-modes", default="",
                    help="comma-separated scenario names (e.g. sweep,wquant) whose "
                         "regressions exit non-zero even without --strict; other "
                         "scenarios stay advisory")
    args = ap.parse_args()
    strict_modes = {m.strip() for m in args.strict_modes.split(",") if m.strip()}

    if not Path(args.prev).exists():
        print(f"bench-compare: no previous report at {args.prev} (first run?) — skipping")
        return 0
    prev, cur = load(args.prev), load(args.cur)
    if not isinstance(prev, dict) or not isinstance(cur, dict):
        print("bench-compare: report is not a JSON object — skipping")
        return 0
    if prev.get("quick") != cur.get("quick"):
        print("bench-compare: quick-mode mismatch between runs — skipping (not comparable)")
        return 0

    # Non-object entries in "samples" (a malformed artifact) are dropped
    # up front: every accessor below assumes dicts.
    prev_samples = [s for s in prev.get("samples", []) if isinstance(s, dict)]
    cur_samples = [s for s in cur.get("samples", []) if isinstance(s, dict)]
    prev_by_key = {key(s): s for s in prev_samples}
    regressions = []
    deltas_by_mode = defaultdict(list)
    for s in cur_samples:
        p = prev_by_key.get(key(s))
        if p is None:
            continue
        name, cur_v = metric(s)
        prev_v = metric(p)[1]
        if prev_v <= 0.0:
            continue
        delta_pct = 100.0 * (cur_v - prev_v) / prev_v
        deltas_by_mode[key(s)[0]].append(delta_pct)
        tag = ""
        if delta_pct < -args.warn_pct:
            tag = "  <-- REGRESSION"
            regressions.append((key(s), delta_pct))
        print(f"  {key(s)}: {name} {prev_v:.2f} -> {cur_v:.2f} "
              f"({delta_pct:+.1f}%){tag}")

    if regressions:
        for k, pct in regressions:
            print(f"::warning title=serve-bench throughput regression::"
                  f"{k}: {pct:+.1f}% vs previous run (threshold -{args.warn_pct:.0f}%)")
    else:
        print(f"bench-compare: no throughput regression beyond {args.warn_pct:.0f}%")

    # Noise summary: |delta| stats per scenario across this pair of
    # runs. Once these sit comfortably under --warn-pct for a few
    # consecutive runs, the threshold is trustworthy and --strict can
    # be flipped on.
    if deltas_by_mode:
        print("bench-compare: noise summary (|delta%| per scenario vs previous run):")
        worst = 0.0
        for mode in sorted(deltas_by_mode):
            ds = [abs(d) for d in deltas_by_mode[mode]]
            worst = max(worst, max(ds))
            print(f"  {mode:<20} mean {sum(ds) / len(ds):5.1f}%  "
                  f"max {max(ds):5.1f}%  (n={len(ds)})")
        verdict = "under" if worst < args.warn_pct else "OVER"
        gating = "gating all scenarios" if args.strict else (
            f"gating {sorted(strict_modes)}" if strict_modes
            else "advisory; --strict not set")
        print(f"  worst scenario noise {worst:.1f}% is {verdict} the "
              f"{args.warn_pct:.0f}% threshold ({gating})")

    gating_regressions = [
        (k, pct) for k, pct in regressions
        if args.strict or k[0] in strict_modes
    ]
    if gating_regressions:
        for k, pct in gating_regressions:
            print(f"bench-compare: gating regression {k}: {pct:+.1f}%")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
