#!/usr/bin/env python3
"""Validate and summarize a serve-path Chrome trace.

Consumes the JSON written by `repro serve --trace-out trace.json`
(Chrome Trace Event Format, the dialect Perfetto's legacy importer
accepts) and acts as both:

* a validator — CI runs this against the bench-smoke trace so a
  malformed export (unbalanced B/E spans, time going backwards within a
  track, missing metadata) fails the job instead of silently producing
  a file Perfetto rejects; and
* a terminal summary — per-phase total duration and counts, per-track
  event totals, so a trace can be sanity-checked without opening a UI.

Checks enforced (exit 1 on any violation):
* top level is an object with "traceEvents" (a list) and
  "displayTimeUnit";
* every event is an object with "name"-or-"ph:E", "ph", "pid", "tid",
  "ts" (E records carry no name by design — the B they close names the
  span);
* within each (pid, tid) track, "ts" is non-decreasing in emitted
  order (the exporter sorts per track; Perfetto tolerates disorder but
  it would mean the merge is wrong);
* within each track, B/E records balance like brackets: no E without
  an open B, no B left open at end-of-track;
* every track with span/instant events has a thread_name metadata
  record ("ph":"M").

Usage: tools/trace_summary.py trace.json [--top N]
Stdlib only (json/argparse) — runs anywhere CI has python3.
"""

import argparse
import json
import sys
from collections import defaultdict


def fail(msg):
    print(f"trace-summary: INVALID: {msg}")
    return 1


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome-trace JSON written by --trace-out")
    ap.add_argument("--top", type=int, default=12,
                    help="phases to list in the duration table (default 12)")
    args = ap.parse_args()

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return fail(f"cannot parse {args.trace}: {e}")

    if not isinstance(doc, dict):
        return fail("top level must be an object (the JSON Object Format), not an array")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return fail('missing or non-list "traceEvents"')
    if "displayTimeUnit" not in doc:
        return fail('missing "displayTimeUnit"')
    if not events:
        return fail("empty traceEvents — the run recorded nothing")

    track_names = {}          # (pid, tid) -> thread_name
    open_spans = defaultdict(list)   # (pid, tid) -> stack of open B names
    last_ts = {}              # (pid, tid) -> last seen ts
    phase_total_us = defaultdict(float)
    phase_count = defaultdict(int)
    instant_count = defaultdict(int)
    track_events = defaultdict(int)
    n_spans = 0

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            return fail(f"event #{i} is not an object")
        ph = ev.get("ph")
        if ph is None:
            return fail(f'event #{i} has no "ph"')
        for k in ("pid", "tid"):
            if k not in ev:
                return fail(f'event #{i} ({ph}) has no "{k}"')
        track = (ev["pid"], ev["tid"])

        if ph == "M":
            if ev.get("name") == "thread_name":
                track_names[track] = ev.get("args", {}).get("name", "?")
            continue

        if "ts" not in ev:
            return fail(f'event #{i} ({ph}) has no "ts"')
        ts = float(ev["ts"])
        if ts < last_ts.get(track, 0.0):
            return fail(f"event #{i}: ts {ts} goes backwards on track {track} "
                        f"(last {last_ts[track]}) — per-track order must be chronological")
        last_ts[track] = ts
        track_events[track] += 1

        if ph == "B":
            name = ev.get("name")
            if not name:
                return fail(f'event #{i}: B record without a "name"')
            open_spans[track].append((name, ts))
        elif ph == "E":
            if not open_spans[track]:
                return fail(f"event #{i}: E at ts {ts} closes nothing on track {track}")
            name, t0 = open_spans[track].pop()
            phase_total_us[name] += ts - t0
            phase_count[name] += 1
            n_spans += 1
        elif ph == "i":
            name = ev.get("name")
            if not name:
                return fail(f'event #{i}: instant without a "name"')
            instant_count[name] += 1
        else:
            return fail(f'event #{i}: unexpected "ph":"{ph}" (exporter only emits M/B/E/i)')

    for track, stack in open_spans.items():
        if stack:
            return fail(f"track {track} ends with {len(stack)} unclosed span(s): "
                        f"{[n for n, _ in stack]}")
    for track in track_events:
        if track not in track_names:
            return fail(f"track {track} has events but no thread_name metadata")
    if n_spans == 0:
        return fail("no completed spans — a serve run always times its phases")

    print(f"trace-summary: {args.trace} OK — {len(events)} events, "
          f"{n_spans} spans, {sum(instant_count.values())} instants, "
          f"{len(track_events)} tracks")
    for track in sorted(track_events):
        print(f"  track {track[1]:>3} {track_names[track]:<24} {track_events[track]:>7} events")
    print(f"  top phases by total duration (of {len(phase_total_us)}):")
    ranked = sorted(phase_total_us.items(), key=lambda kv: -kv[1])
    for name, us in ranked[:args.top]:
        print(f"    {name:<20} {us / 1e3:>10.3f} ms  x{phase_count[name]}")
    if instant_count:
        shown = sorted(instant_count.items(), key=lambda kv: -kv[1])
        print("  instants: " + ", ".join(f"{n} x{c}" for n, c in shown))
    return 0


if __name__ == "__main__":
    sys.exit(main())
