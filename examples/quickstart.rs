//! Quickstart: the Fig. 2 phase-ordering example, end to end.
//!
//! Builds the transpose-laden graph, shows the greedy rewriter's
//! order-dependent results, then saturates an e-graph with the Table-1
//! rules and extracts the optimum with the Roofline-weighted WPMaxSAT
//! extractor — all transposes gone regardless of rule order.
//!
//! Run: `cargo run --release --example quickstart`

use nncase_repro::cost::MachineSpec;
use nncase_repro::egraph::{extract_wpmaxsat, roofline_cost_fn, EGraph, Runner};
use nncase_repro::ir::{BinaryKind, DType, Graph, UnaryKind};
use nncase_repro::rewrite::greedy::{count_transposes, greedy_rewrite, GreedyOrder};
use nncase_repro::rewrite::transpose_rules;

fn main() {
    // out = T(Add(T(A), Exp(T(B)))) — Fig. 2(a).
    let mut g = Graph::new();
    let a = g.input("A", &[256, 256], DType::F32);
    let b = g.input("B", &[256, 256], DType::F32);
    let ta = g.transpose(a, &[1, 0]);
    let tb = g.transpose(b, &[1, 0]);
    let ub = g.unary(UnaryKind::Exp, tb);
    let sum = g.binary(BinaryKind::Add, ta, ub);
    let out = g.transpose(sum, &[1, 0]);
    g.mark_output(out);

    println!("== input graph (Fig. 2a) ==\n{}", g.dump());
    println!("transposes: {}\n", count_transposes(&g));

    // Destructive greedy rewriting: the result depends on rule order.
    for order in [GreedyOrder::LeftFirst, GreedyOrder::RightFirst] {
        let (h, apps) = greedy_rewrite(&g, order);
        println!(
            "greedy {order:?}: {} transposes after {apps} rule applications",
            count_transposes(&h)
        );
    }

    // Equality saturation: all orders explored at once.
    let (mut eg, map) = EGraph::from_graph(&g);
    let rules = transpose_rules();
    let refs: Vec<&dyn nncase_repro::egraph::Rewrite> =
        rules.iter().map(|r| r.as_ref()).collect();
    let report = Runner::new(&mut eg).run(&refs);
    println!(
        "\ne-graph: {} nodes / {} classes, saturated={} in {} iters",
        report.nodes, report.classes, report.saturated, report.iterations
    );

    let machine = MachineSpec::ryzen_5900x();
    let cost = roofline_cost_fn(&machine);
    let ex = extract_wpmaxsat(&eg, &[map[out.index()]], &cost);
    println!(
        "extracted (WPMaxSAT, roofline weights): cost {} ns, {} transposes",
        ex.cost,
        count_transposes(&ex.graph)
    );
    println!("\n== optimized graph (Fig. 2f) ==\n{}", ex.graph.dump());
    assert_eq!(count_transposes(&ex.graph), 0);
    println!("quickstart OK");
}
