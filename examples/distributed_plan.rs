//! Auto Distribution demo (§3.1.3): SBP strategy search on a transformer
//! MLP over "cores as distributed nodes" placements.
//!
//! Shows: the distributed e-graph (e-clusters per logical node), the
//! extracted strategy at 2/4/8 devices with compute vs communication
//! split, and the hard memory constraint rejecting broadcast-heavy
//! strategies (Observation 2).
//!
//! Run: `cargo run --release --example distributed_plan`

use nncase_repro::cost::MachineSpec;
use nncase_repro::dist::{build_dist_egraph, extract_dist, DistError, Placement};
use nncase_repro::ir::{DType, Graph, UnaryKind};
use nncase_repro::util::human_bytes;

fn mlp(batch: usize, hidden: usize, inter: usize) -> Graph {
    let mut g = Graph::new();
    let x = g.input("x", &[batch, hidden], DType::F32);
    let w1 = g.constant("w_gate", &[hidden, inter], DType::F32);
    let w2 = g.constant("w_down", &[inter, hidden], DType::F32);
    let h = g.matmul(x, w1);
    let a = g.unary(UnaryKind::Silu, h);
    let out = g.matmul(a, w2);
    g.mark_output(out);
    g
}

fn main() {
    let machine = MachineSpec::ryzen_5900x();
    let g = mlp(8, 1024, 3072);
    println!("== logical MLP ==\n{}", g.dump());

    for devices in [2usize, 4, 8] {
        let placement = Placement::line(devices);
        let d = build_dist_egraph(&g, &placement);
        println!(
            "-- {devices} devices: distributed e-graph has {} e-nodes / {} e-classes",
            d.egraph.n_nodes,
            d.egraph.num_classes()
        );
        // Show one e-cluster: the first matmul's SBP entries (Fig. 6).
        let mm = g
            .live_nodes()
            .into_iter()
            .find(|&id| matches!(g.node(id).op, nncase_repro::ir::Op::MatMul))
            .unwrap();
        let mut keys: Vec<String> =
            d.clusters[mm.index()].keys().map(|k| k.to_string()).collect();
        keys.sort();
        println!("   matmul e-cluster SBP entries: {}", keys.join(" "));

        let sol = extract_dist(&d, &machine, u64::MAX / 4, true).unwrap();
        println!(
            "   strategy: total {:.1} us (comm {:.1} us), weight shard/device {}",
            sol.total_ns as f64 / 1e3,
            sol.comm_ns as f64 / 1e3,
            human_bytes(sol.weight_bytes_per_device as usize)
        );
        for c in sol.choices.iter().take(4) {
            println!("     node %{} -> {}", c.node.0, c.sbp);
        }
    }

    // Memory constraint demo: full weights are 2*1024*3072*4 = 24 MiB;
    // a 16 MiB per-device cap forces split weights, an impossible cap errors.
    let placement = Placement::line(2);
    let d = build_dist_egraph(&g, &placement);
    let capped = extract_dist(&d, &machine, 16 << 20, true).unwrap();
    println!(
        "\nwith 16 MiB/device cap: shard/device {} (<= cap, Broadcast rejected)",
        human_bytes(capped.weight_bytes_per_device as usize)
    );
    match extract_dist(&d, &machine, 1 << 20, true) {
        Err(DistError::OutOfMemory { required_bytes, capacity_bytes }) => println!(
            "with 1 MiB/device cap: OOM as expected (needs {} > {})",
            human_bytes(required_bytes as usize),
            human_bytes(capacity_bytes as usize)
        ),
        other => panic!("expected OOM, got {other:?}"),
    }
    println!("distributed_plan OK");
}
