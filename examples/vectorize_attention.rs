//! Auto Vectorize on the Fig. 3 attention-like subgraph.
//!
//! O = MatMul(Exp(MatMul(Q, K)), V). MetaPackOperation generates every
//! pack/compute/unpack candidate; FoldNopPack cancels the interior
//! conversions; extraction keeps the data in the blocked `<16,16>` layout
//! through the whole chain (Eq. 1). If the AOT artifacts are present the
//! same fused kernel (the L1 Pallas version) is executed through PJRT and
//! checked against the Rust NTT composition.
//!
//! Run: `cargo run --release --example vectorize_attention`

use nncase_repro::cost::MachineSpec;
use nncase_repro::ir::{DType, Graph, Op, UnaryKind};
use nncase_repro::pipeline::{CompileOptions, Compiler};

fn main() {
    let mut g = Graph::new();
    let q = g.input("Q", &[64, 64], DType::F32);
    let k = g.input("K", &[64, 64], DType::F32);
    let v = g.input("V", &[64, 64], DType::F32);
    let s = g.matmul(q, k);
    let e = g.unary(UnaryKind::Exp, s);
    let o = g.matmul(e, v);
    g.mark_output(o);
    println!("== logical graph ==\n{}", g.dump());

    let compiler = Compiler::new(MachineSpec::ryzen_5900x(), CompileOptions::default());
    let m = compiler.compile(&g);
    println!("== vectorized graph (pass-through blocked layout) ==\n{}", m.graph.dump());

    let live = m.graph.live_nodes();
    let n_pack =
        live.iter().filter(|&&id| matches!(m.graph.node(id).op, Op::Pack { .. })).count();
    let n_unpack =
        live.iter().filter(|&&id| matches!(m.graph.node(id).op, Op::Unpack { .. })).count();
    println!("packs: {n_pack} (Q, K, V), unpacks: {n_unpack} (O only)");
    println!("\n== generated NTT C++ (Fig. 8 style) ==\n{}", m.emit_cpp("attention_like"));

    // Execute the L1 Pallas fused kernel through PJRT if available.
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.tsv").exists() && nncase_repro::runtime::PjrtRuntime::available() {
        use nncase_repro::ntt::{exp_inplace, matmul_blocked, Tensor};
        use nncase_repro::runtime::{Manifest, PjrtRuntime};
        use nncase_repro::util::Rng;
        let manifest = Manifest::load(&dir.join("manifest.tsv")).unwrap();
        let mut rt = PjrtRuntime::cpu(dir).unwrap();
        let entry = manifest.get("attention_32x64").unwrap();
        rt.load("attn", &entry.path).unwrap();
        let mut rng = Rng::new(1);
        let (mm, d) = (32usize, 64usize);
        let qd = Tensor::randn(&[mm, d], &mut rng, 0.3);
        let kd = Tensor::randn(&[d, mm], &mut rng, 0.3);
        let vd = Tensor::randn(&[mm, d], &mut rng, 0.3);
        let out = rt
            .run_f32("attn", &[(&qd.data, &[mm, d]), (&kd.data, &[d, mm]), (&vd.data, &[mm, d])])
            .unwrap();
        let mut sref = matmul_blocked(&qd, &kd);
        exp_inplace(&mut sref.data);
        let want = matmul_blocked(&sref, &vd);
        let diff = out[0]
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        println!("\nPallas fused kernel vs NTT composition: max |Δ| = {diff:.2e}");
        assert!(diff < 1e-2);
    } else {
        println!(
            "\n(PJRT check skipped — needs `make artifacts` and an xla-enabled build)"
        );
    }
    println!("vectorize_attention OK");
}
