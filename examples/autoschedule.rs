//! Auto Schedule demo (§3.2): MCTS structural search + MINLP parametric
//! optimization on the Fig. 7 attention kernel.
//!
//! Prints the initial tiered tile graph in the Eq. 3 notation, the MCTS
//! action trace, the solved tile sizes / buffer placements, and the
//! red-box-vs-green-box comparison (all-ones tiles vs solved tiles).
//!
//! Run: `cargo run --release --example autoschedule`

use nncase_repro::cost::MachineSpec;
use nncase_repro::ir::{DType, Graph, UnaryKind};
use nncase_repro::schedule::{
    autoschedule, solve_parametric, subgraph_to_tileops, MctsConfig, MinlpConfig, TiledState,
};

fn main() {
    // T1 = MatMul(Q, K); T2 = Exp(T1); O = MatMul(T2, V)  (Fig. 7).
    let mut g = Graph::new();
    let q = g.input("Q", &[512, 256], DType::F32);
    let k = g.input("K", &[256, 512], DType::F32);
    let v = g.input("V", &[512, 256], DType::F32);
    let t1 = g.matmul(q, k);
    let t2 = g.unary(UnaryKind::Exp, t1);
    let o = g.matmul(t2, v);
    g.mark_output(o);

    let nodes = g.live_nodes();
    let ops = subgraph_to_tileops(&g, &nodes);
    let machine = MachineSpec::ryzen_5900x();
    let levels = machine.caches.len(); // L1, L2, L3
    let init = TiledState::initial(ops, levels);
    println!("== initial tiered tile graph (Eq. 3 notation) ==\n{}", init.notation());

    let base = solve_parametric(&init, &machine, &MinlpConfig::default()).unwrap();
    println!(
        "unfused structure: latency {:.1} us (T_comp {:.1} us, T_mem {:.1} us)",
        base.latency_s * 1e6,
        base.t_comp_s * 1e6,
        base.t_mem_s * 1e6
    );

    let cfg = MctsConfig { iterations: 200, ..Default::default() };
    let res = autoschedule(init, &machine, cfg).expect("schedule");
    println!("\n== MCTS result ({} MINLP evaluations) ==", res.evaluations);
    println!("actions: {:?}", res.actions);
    println!("{}", res.state.notation());
    println!(
        "best latency {:.1} us (T_comp {:.1} us, T_mem {:.1} us)",
        res.solution.latency_s * 1e6,
        res.solution.t_comp_s * 1e6,
        res.solution.t_mem_s * 1e6
    );
    println!("tile extents per level (innermost first):");
    for (l, ext) in res.solution.extents.iter().enumerate() {
        let mut dims: Vec<_> = ext.iter().collect();
        dims.sort();
        let s: Vec<String> = dims.iter().map(|(d, e)| format!("{d}={e}")).collect();
        println!("  L{l}: {}", s.join(" "));
    }
    let mut placements: Vec<_> = res.solution.placement.iter().collect();
    placements.sort();
    println!("buffer placements (memory level): {placements:?}");

    assert!(res.solution.latency_s <= base.latency_s * 1.0001);
    println!("autoschedule OK");
}
