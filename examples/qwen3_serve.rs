//! **E2E driver**: serve real batched requests on the Qwen3-tiny model
//! with real numerics end to end, proving the three layers compose:
//!
//! * weights come from `artifacts/weights.bin` (written by the L2/L1
//!   python build, the exact tensors baked into the JAX decode artifact
//!   that integration tests check against this engine), falling back to
//!   deterministic random weights when artifacts are absent;
//! * the serving coordinator (L3) runs the decode loop with static
//!   per-core partitioning ("cores as distributed nodes", §4.2);
//! * latency and throughput are measured per thread count, showing the
//!   multi-core scaling story of Figure 10 on real execution.
//!
//! Run: `cargo run --release --example qwen3_serve`
//! (add `-- --kv-cold-blocks 96 [--kv-quant int8|f32]` for the tiered
//! KV-storage demo over a deliberately small hot pool,
//! `--prefill-chunk N` to change the chunked-prefill span width,
//! `--shards N` to pick the worker-group count of the dist-sharded
//! run, `--trace-out trace.json` to keep the traced run's per-worker
//! timeline as Chrome-trace JSON for Perfetto, and
//! `--weight-quant int8|int4` to store the GEMM weight plane as
//! group-wise codes streamed through the fused dequant-GEMM kernels —
//! the FCFS engine then runs the fake-quantized oracle weights, so the
//! cross-engine equality asserts below still hold bitwise).
//! An autotuned continuous run (every knob from the serve-time
//! planner, `ContinuousConfig::autotuned`) always executes and must
//! match the same outputs — serve plans are semantics-free.
//! The run is recorded in EXPERIMENTS.md §E2E.

use nncase_repro::coordinator::{synthetic_workload, Coordinator, Qwen3Engine, ServeOptions};
use nncase_repro::model::{Qwen3Config, Qwen3Weights};
use nncase_repro::ntt::WeightQuant;
use nncase_repro::serving::{ContinuousConfig, KvQuant, TierConfig};

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let wq = match opt(&args, "--weight-quant") {
        Some(q) => {
            WeightQuant::parse(&q).unwrap_or_else(|| panic!("bad --weight-quant {q:?}"))
        }
        None => WeightQuant::F32,
    };
    let cfg = Qwen3Config::tiny().with_weight_quant(wq);
    let weights_path = std::path::Path::new("artifacts/weights.bin");
    let load = |()| -> Qwen3Weights {
        if weights_path.exists() {
            println!("weights: artifacts/weights.bin (shared with the JAX artifact)");
            Qwen3Weights::from_file(&cfg, weights_path).expect("weights.bin")
        } else {
            println!("weights: deterministic random (run `make artifacts` to share with JAX)");
            Qwen3Weights::random(&cfg, 42)
        }
    };
    println!(
        "model: {} — {} params, {} weight bytes [{}], vocab {}",
        cfg.name,
        cfg.param_count(),
        nncase_repro::util::human_bytes(cfg.weight_bytes() as usize),
        cfg.weight_quant.name(),
        cfg.vocab
    );

    let requests = synthetic_workload(8, 8, 32, cfg.vocab);
    println!(
        "workload: {} requests x (8-token prompt + 32 generated tokens)\n",
        requests.len()
    );

    let mut last_output = None;
    for threads in [1usize, 2, 4] {
        let engine = Qwen3Engine::new(load(()), threads, 512);
        let mut coord = Coordinator::new(engine);
        let report = coord.serve(&requests, &ServeOptions::fcfs());
        println!("threads={threads}: {}", report.render());
        // Decode output must be identical across thread counts (static
        // partitioning preserves numerics).
        if let Some(prev) = &last_output {
            assert_eq!(prev, &report.outputs, "thread count changed outputs!");
        }
        last_output = Some(report.outputs);
    }
    // Continuous batching over the paged KV pool: same outputs, one
    // weight stream per iteration instead of per request, and the
    // batched step itself runs SPMD across persistent workers — the
    // static partition keeps outputs identical at every thread count
    // (docs/serving.md).
    for threads in [1usize, 4] {
        let engine = Qwen3Engine::new(load(()), 1, 512);
        let mut coord = Coordinator::new(engine);
        let ccfg = ContinuousConfig::builder()
            .block_size(16)
            .num_blocks(64)
            .max_batch(requests.len())
            .build();
        let report = coord.serve(&requests, &ServeOptions::continuous(ccfg).threads(threads));
        println!("continuous ({} workers): {}", report.threads, report.render());
        assert_eq!(
            last_output.as_ref().unwrap(),
            &report.outputs,
            "continuous batching changed outputs!"
        );
    }

    // Chunked prefill (`--prefill-chunk N`, default 16 here): prompt
    // ingestion runs as multi-token spans — tall GEMMs instead of
    // batch-of-one steps — and must stay token-identical to chunk 1
    // (only TTFT and iteration counts change).
    let chunk: usize =
        opt(&args, "--prefill-chunk").and_then(|v| v.parse().ok()).unwrap_or(16);
    {
        let engine = Qwen3Engine::new(load(()), 1, 512);
        let mut coord = Coordinator::new(engine);
        let ccfg = ContinuousConfig::builder()
            .block_size(16)
            .num_blocks(64)
            .max_batch(requests.len())
            .prefill_chunk(chunk)
            .build();
        let report = coord.serve(&requests, &ServeOptions::continuous(ccfg));
        println!("chunked prefill (chunk {chunk}): {}", report.render());
        assert_eq!(
            last_output.as_ref().unwrap(),
            &report.outputs,
            "chunked prefill changed outputs!"
        );
    }

    // Serve-time autotune: every knob (chunk, budget, threads, panel
    // granularity, pool sizing) from the planner — schedule::tile
    // candidates scored by the cost rooflines for this
    // (model, machine, quant) triple — instead of the constants above.
    // The plan is a pure perf artifact, so outputs must stay identical
    // to every run above.
    {
        let machine = nncase_repro::cost::MachineSpec::ryzen_5900x();
        let ccfg = ContinuousConfig::autotuned(&cfg, &machine, requests.len());
        let plan = ccfg.plan.clone().expect("autotuned config carries its plan");
        println!("autotune plan: {}", plan.render());
        let engine = Qwen3Engine::new(load(()), 1, 512);
        let mut coord = Coordinator::new(engine);
        let report = coord.serve(&requests, &ServeOptions::continuous(ccfg));
        println!("autotuned continuous: {}", report.render());
        assert_eq!(
            last_output.as_ref().unwrap(),
            &report.outputs,
            "the serve plan changed outputs — plans must be semantics-free!"
        );
        assert_eq!(
            report.plan.as_ref().map(|p| p.plan_hash()),
            Some(plan.plan_hash()),
            "the report must record the plan that served"
        );
    }

    // Serve-path tracing (`--trace-out trace.json` keeps the Chrome
    // trace for Perfetto): the same continuous run with per-worker
    // phase timelines recorded into pre-allocated rings. Tracing is
    // observability only, so outputs must stay bitwise identical to
    // the untraced runs above; the merged summary (phase breakdown,
    // per-worker busy/wait) rides on the report.
    {
        let engine = Qwen3Engine::new(load(()), 1, 512);
        let mut coord = Coordinator::new(engine);
        let ccfg = ContinuousConfig::builder()
            .block_size(16)
            .num_blocks(64)
            .max_batch(requests.len())
            .build();
        let trace_out = opt(&args, "--trace-out");
        let mut opts = ServeOptions::continuous(ccfg).threads(2).trace();
        if let Some(path) = &trace_out {
            opts = opts.trace_out(path.clone());
        }
        let report = coord.serve(&requests, &opts);
        println!("traced continuous: {}", report.render());
        let t = report.trace.as_ref().expect("traced run carries a summary");
        for w in &t.workers {
            println!(
                "  {:<22} busy {:>8.3} ms  wait {:>8.3} ms ({:>4.1}% waiting)",
                w.name,
                w.busy_s * 1e3,
                w.wait_s * 1e3,
                100.0 * w.wait_frac()
            );
        }
        if let Some(path) = &trace_out {
            println!("  trace -> {path} (open in https://ui.perfetto.dev)");
        }
        assert_eq!(
            last_output.as_ref().unwrap(),
            &report.outputs,
            "tracing changed outputs — observability must be semantics-free!"
        );
    }

    // Dist-sharded serving (`--shards N`, default 2): each projection
    // GEMM is partitioned across N cooperating worker groups, with the
    // split-vs-broadcast layout chosen per weight matrix by the dist
    // cost model (`dist::extract_dist` + reshard pricing). The
    // cross-shard combine is disjoint column placement — never a
    // floating-point reduction — so outputs stay bitwise identical to
    // every run above at any (threads x shards).
    {
        let shards: usize = opt(&args, "--shards").and_then(|v| v.parse().ok()).unwrap_or(2);
        let machine = nncase_repro::cost::MachineSpec::test_numa();
        let engine = Qwen3Engine::new(load(()), 1, 512);
        let mut coord = Coordinator::new(engine);
        let ccfg = ContinuousConfig::builder()
            .block_size(16)
            .num_blocks(64)
            .max_batch(requests.len())
            .build();
        let opts =
            ServeOptions::continuous(ccfg).threads(2).shards(shards).machine(machine);
        let report = coord.serve(&requests, &opts);
        println!("sharded continuous ({shards} shard groups): {}", report.render());
        if let Some(sig) = &report.sbp_sig {
            println!("dist-chosen layouts: {sig}");
        }
        assert_eq!(
            last_output.as_ref().unwrap(),
            &report.outputs,
            "sharded serving changed outputs!"
        );
    }

    // Tiered KV storage (`--kv-cold-blocks N [--kv-quant int8|f32]`):
    // re-run continuous over a deliberately small hot pool backed by the
    // cold tier, so swap-based preemption actually fires. The f32 tier
    // is lossless — outputs must still match; int8 may diverge after a
    // spilled block is re-read (the report's swap metrics say when).
    if let Some(cold_blocks) = opt(&args, "--kv-cold-blocks").and_then(|v| v.parse().ok()) {
        let quant = match opt(&args, "--kv-quant") {
            Some(q) => KvQuant::parse(&q).unwrap_or_else(|| panic!("bad --kv-quant {q:?}")),
            None => KvQuant::Int8,
        };
        let tier = TierConfig { quant, ..TierConfig::new(cold_blocks) };
        let engine = Qwen3Engine::new(load(()), 1, 512);
        let mut coord = Coordinator::new(engine);
        let ccfg = ContinuousConfig::builder()
            .block_size(4)
            // Well under the 8-sequence working set (8 x 11 blocks)
            // but enough for one full sequence plus headroom.
            .num_blocks(14)
            .max_batch(requests.len())
            .tiering(tier)
            .build();
        let report = coord.serve(&requests, &ServeOptions::continuous(ccfg));
        println!("tiered continuous: {}", report.render());
        let m = report.serving.as_ref().expect("continuous metrics");
        assert!(m.preemptions > 0, "the small hot pool must force preemption");
        if m.recompute_preemptions > 0 {
            // A cold tier too small for the swap sets degrades to
            // recompute (possibly for every preemption) — report it
            // rather than panicking on a valid, if unhelpful, flag.
            println!(
                "note: cold tier of {cold_blocks} blocks overflowed; {} of {} preemptions \
                 fell back to recompute",
                m.recompute_preemptions, m.preemptions
            );
        }
        // Recompute and f32 swap are both exact, so f32 runs must match
        // regardless of how preemptions were resolved.
        if quant == KvQuant::F32 {
            assert_eq!(
                last_output.as_ref().unwrap(),
                &report.outputs,
                "lossless (f32) swap changed outputs!"
            );
        }
    }

    let sample = &last_output.unwrap()[0].1;
    println!("\nsample generation (request 0): {:?}", &sample[..12.min(sample.len())]);
    println!("qwen3_serve OK");
}
