//! **E2E driver**: serve real batched requests on the Qwen3-tiny model
//! with real numerics end to end, proving the three layers compose:
//!
//! * weights come from `artifacts/weights.bin` (written by the L2/L1
//!   python build, the exact tensors baked into the JAX decode artifact
//!   that integration tests check against this engine), falling back to
//!   deterministic random weights when artifacts are absent;
//! * the serving coordinator (L3) runs the decode loop with static
//!   per-core partitioning ("cores as distributed nodes", §4.2);
//! * latency and throughput are measured per thread count, showing the
//!   multi-core scaling story of Figure 10 on real execution.
//!
//! Run: `cargo run --release --example qwen3_serve`
//! The run is recorded in EXPERIMENTS.md §E2E.

use nncase_repro::coordinator::{synthetic_workload, Coordinator, Qwen3Engine, ServePolicy};
use nncase_repro::model::{Qwen3Config, Qwen3Weights};
use nncase_repro::serving::ContinuousConfig;

fn main() {
    let cfg = Qwen3Config::tiny();
    let weights_path = std::path::Path::new("artifacts/weights.bin");
    let load = |()| -> Qwen3Weights {
        if weights_path.exists() {
            println!("weights: artifacts/weights.bin (shared with the JAX artifact)");
            Qwen3Weights::from_file(&cfg, weights_path).expect("weights.bin")
        } else {
            println!("weights: deterministic random (run `make artifacts` to share with JAX)");
            Qwen3Weights::random(&cfg, 42)
        }
    };
    println!(
        "model: {} — {} params, {} weight bytes, vocab {}",
        cfg.name,
        cfg.param_count(),
        nncase_repro::util::human_bytes(cfg.weight_bytes() as usize),
        cfg.vocab
    );

    let requests = synthetic_workload(8, 8, 32, cfg.vocab);
    println!(
        "workload: {} requests x (8-token prompt + 32 generated tokens)\n",
        requests.len()
    );

    let mut last_output = None;
    for threads in [1usize, 2, 4] {
        let engine = Qwen3Engine::new(load(()), threads, 512);
        let mut coord = Coordinator::new(engine);
        let report = coord.serve(&requests);
        println!("threads={threads}: {}", report.render());
        // Decode output must be identical across thread counts (static
        // partitioning preserves numerics).
        if let Some(prev) = &last_output {
            assert_eq!(prev, &report.outputs, "thread count changed outputs!");
        }
        last_output = Some(report.outputs);
    }
    // Continuous batching over the paged KV pool: same outputs, one
    // weight stream per iteration instead of per request, and the
    // batched step itself runs SPMD across persistent workers — the
    // static partition keeps outputs identical at every thread count
    // (docs/serving.md).
    for threads in [1usize, 4] {
        let engine = Qwen3Engine::new(load(()), 1, 512);
        let mut coord = Coordinator::new(engine);
        let report = coord.serve_with_policy(
            &requests,
            ServePolicy::Continuous(ContinuousConfig {
                block_size: 16,
                num_blocks: 64,
                max_batch: requests.len(),
                threads,
            }),
        );
        println!("continuous ({} workers): {}", report.threads, report.render());
        assert_eq!(
            last_output.as_ref().unwrap(),
            &report.outputs,
            "continuous batching changed outputs!"
        );
    }

    let sample = &last_output.unwrap()[0].1;
    println!("\nsample generation (request 0): {:?}", &sample[..12.min(sample.len())]);
    println!("qwen3_serve OK");
}
