"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every property is the core
correctness signal for the artifacts the Rust runtime executes.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.kernels.attention import attention_exp
from compile.kernels.matmul import matmul, mxu_utilization, vmem_footprint_bytes
from compile.kernels.rmsnorm import rmsnorm

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed, dtype=jnp.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale, dtype)


blocks = st.sampled_from([16, 32])
mults = st.integers(min_value=1, max_value=4)


@hypothesis.given(bm=blocks, mi=mults, ki=mults, ni=mults, seed=st.integers(0, 2**31))
@hypothesis.settings(max_examples=20, deadline=None)
def test_matmul_matches_ref_shapes(bm, mi, ki, ni, seed):
    m, k, n = bm * mi, 16 * ki, 16 * ni
    x = rand((m, k), seed)
    y = rand((k, n), seed + 1)
    got = matmul(x, y, bm=bm, bk=16, bn=16)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    x = rand((32, 32), 7, dtype)
    y = rand((32, 32), 8, dtype)
    got = matmul(x, y)
    want = ref.matmul_ref(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_matmul_rejects_k_mismatch():
    with pytest.raises(AssertionError):
        matmul(rand((16, 32), 0), rand((16, 16), 1))


def test_matmul_degrades_blocks_for_thin_shapes():
    # The M=1 decode GEMV and prime M both fall back to smaller blocks.
    x = rand((1, 32), 2)
    y = rand((32, 32), 3)
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)
    x = rand((17, 16), 4)
    y = rand((16, 16), 5)
    np.testing.assert_allclose(matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)


@hypothesis.given(
    mi=st.integers(1, 4), d=st.sampled_from([32, 64]), seed=st.integers(0, 2**31)
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_attention_fused_matches_ref(mi, d, seed):
    m = 16 * mi
    q = rand((m, d), seed, scale=0.3)
    k = rand((d, m), seed + 1, scale=0.3)
    v = rand((m, d), seed + 2, scale=0.3)
    got = attention_exp(q, k, v, bm=16)
    want = ref.attention_exp_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@hypothesis.given(
    rows=st.sampled_from([1, 4, 8, 16]),
    h=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**31),
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_rmsnorm_matches_ref(rows, h, seed):
    x = rand((rows, h), seed)
    w = rand((h,), seed + 1, scale=0.5)
    got = rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_rmsnorm_unit_rows():
    x = jnp.full((2, 64), 3.0)
    w = jnp.ones((64,))
    out = rmsnorm(x, w)
    np.testing.assert_allclose(out, jnp.ones_like(x), rtol=1e-5)


def test_rope_ref_properties():
    x = rand((64,), 5)
    # pos 0 is the identity.
    np.testing.assert_allclose(ref.rope_ref(x, 0.0, 1e4), x, rtol=1e-6)
    # Norm preserved (rotation).
    y = ref.rope_ref(x, 13.0, 1e4)
    np.testing.assert_allclose(
        jnp.linalg.norm(y), jnp.linalg.norm(x), rtol=1e-5
    )


def test_vmem_and_mxu_models():
    # Analytical §Perf metrics behave sensibly.
    assert vmem_footprint_bytes(16, 16, 16) == 4 * (2 * (256 + 256) + 256)
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(16, 16, 16) < 0.02
    assert vmem_footprint_bytes(256, 256, 256) < 16 * 2**20, "fits VMEM"
