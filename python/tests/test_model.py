"""L2 correctness: the JAX decode step (shapes, caching semantics, jit
parity) for the tiny Qwen3 model whose HLO the Rust runtime executes."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    TinyConfig,
    decode_step,
    decode_step_fn,
    init_params,
    weight_specs,
)

jax.config.update("jax_platform_name", "cpu")

CFG = TinyConfig()


def caches():
    kvd = CFG.kv_heads * CFG.head_dim
    z = jnp.zeros((CFG.layers, CFG.max_seq, kvd))
    return z, jnp.zeros_like(z)


def test_weight_specs_cover_tiny_param_count():
    # Matches rust Qwen3Config::tiny() param accounting (minus the QK-norm
    # pair the rust count includes as an upper bound).
    total = sum(int(np.prod(s)) for _, s in weight_specs(CFG))
    assert 3_000_000 < total < 30_000_000


def test_decode_step_shapes():
    params = init_params(CFG, 0)
    k, v = caches()
    x = params["embedding"][5][None, :]
    logits, knew, vnew = decode_step(params, CFG, x, k, v, jnp.int32(0))
    assert logits.shape == (1, CFG.vocab)
    assert knew.shape == (CFG.layers, CFG.kv_heads * CFG.head_dim)
    assert vnew.shape == knew.shape
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_cache_changes_logits():
    params = init_params(CFG, 0)
    k, v = caches()
    x = params["embedding"][5][None, :]
    l0, knew, vnew = decode_step(params, CFG, x, k, v, jnp.int32(0))
    k = k.at[:, 0, :].set(knew)
    v = v.at[:, 0, :].set(vnew)
    l1, _, _ = decode_step(params, CFG, x, k, v, jnp.int32(1))
    assert float(jnp.max(jnp.abs(l0 - l1))) > 1e-7


def test_masking_ignores_future_rows():
    # Garbage in cache rows >= pos must not affect the result.
    params = init_params(CFG, 0)
    k, v = caches()
    x = params["embedding"][9][None, :]
    l_clean, _, _ = decode_step(params, CFG, x, k, v, jnp.int32(0))
    k_dirty = k.at[:, 3:, :].set(999.0)
    v_dirty = v.at[:, 3:, :].set(-999.0)
    l_dirty, _, _ = decode_step(params, CFG, x, k_dirty, v_dirty, jnp.int32(0))
    np.testing.assert_allclose(l_clean, l_dirty, rtol=1e-6)


def test_jit_matches_eager():
    fn, params = decode_step_fn(CFG, 0)
    jfn = jax.jit(fn)
    k, v = caches()
    x = params["embedding"][17][None, :]
    le, ke, ve = fn(x, k, v, jnp.int32(0))
    lj, kj, vj = jfn(x, k, v, jnp.int32(0))
    np.testing.assert_allclose(le, lj, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ke, kj, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ve, vj, rtol=1e-5, atol=1e-6)


def test_deterministic_params():
    a = init_params(CFG, 3)
    b = init_params(CFG, 3)
    np.testing.assert_array_equal(a["l0.wq"], b["l0.wq"])
    c = init_params(CFG, 4)
    assert float(jnp.max(jnp.abs(a["l0.wq"] - c["l0.wq"]))) > 0
