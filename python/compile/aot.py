"""AOT lowering: JAX/Pallas -> HLO **text** artifacts for the Rust PJRT
runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  kernels/matmul_<m>x<k>x<n>.hlo.txt   L1 blocked matmul (several shapes)
  kernels/attention_<m>x<d>.hlo.txt    L1 fused exp-attention (Fig. 3)
  kernels/rmsnorm_<r>x<h>.hlo.txt      L1 rmsnorm
  decode_tiny.hlo.txt                  L2 full decode step, weights baked
  weights.bin                          the baked weights, flat f32 LE
  manifest.tsv                         name<TAB>path<TAB>k=v...
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels.attention import attention_exp
from .kernels.matmul import matmul
from .kernels.rmsnorm import rmsnorm
from .model import TinyConfig, decode_step_args_fn, decode_step_fn, weight_specs


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower(fn, *specs):
    return jax.jit(fn).lower(*specs)


def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(os.path.join(out, "kernels"), exist_ok=True)
    manifest = []

    def emit(name, rel, lowered, **meta):
        text = to_hlo_text(lowered)
        path = os.path.join(out, rel)
        with open(path, "w") as f:
            f.write(text)
        kv = "\t".join(f"{k}={v}" for k, v in meta.items())
        manifest.append(f"{name}\t{rel}" + ("\t" + kv if kv else ""))
        print(f"  {name}: {len(text)} chars -> {rel}")

    # ---- L1 kernels --------------------------------------------------
    for m, k, n in [(16, 16, 16), (64, 64, 64), (64, 128, 32)]:
        fn = lambda x, y: (matmul(x, y),)
        emit(
            f"matmul_{m}x{k}x{n}",
            f"kernels/matmul_{m}x{k}x{n}.hlo.txt",
            lower(fn, f32((m, k)), f32((k, n))),
            m=m, k=k, n=n,
        )
    for m, d in [(32, 64)]:
        fn = lambda q, k, v: (attention_exp(q, k, v),)
        emit(
            f"attention_{m}x{d}",
            f"kernels/attention_{m}x{d}.hlo.txt",
            lower(fn, f32((m, d)), f32((d, m)), f32((m, d))),
            m=m, d=d,
        )
    for r, h in [(8, 256)]:
        fn = lambda x, w: (rmsnorm(x, w),)
        emit(
            f"rmsnorm_{r}x{h}",
            f"kernels/rmsnorm_{r}x{h}.hlo.txt",
            lower(fn, f32((r, h)), f32((h,))),
            rows=r, hidden=h,
        )

    # ---- L2 decode step (weights as positional arguments) -------------
    # HLO text elides large constants, so weights travel via weights.bin
    # and are fed as arguments (see model.decode_step_args_fn docstring).
    cfg = TinyConfig()
    _, params = decode_step_fn(cfg, args.seed)
    afn, specs = decode_step_args_fn(cfg)
    kvd = cfg.kv_heads * cfg.head_dim
    arg_specs = [f32(shape) for _, shape in specs] + [
        f32((1, cfg.hidden)),
        f32((cfg.layers, cfg.max_seq, kvd)),
        f32((cfg.layers, cfg.max_seq, kvd)),
        jax.ShapeDtypeStruct((), jnp.int32),
    ]
    lowered = jax.jit(afn).lower(*arg_specs)
    emit(
        "decode_tiny",
        "decode_tiny.hlo.txt",
        lowered,
        hidden=cfg.hidden, layers=cfg.layers, max_seq=cfg.max_seq,
        vocab=cfg.vocab, n_weight_args=len(specs),
    )

    # ---- weights.bin (same tensors the HLO bakes) ---------------------
    with open(os.path.join(out, "weights.bin"), "wb") as f:
        for name, shape in weight_specs(cfg):
            arr = np.asarray(params[name], dtype=np.float32)
            assert arr.shape == tuple(shape), name
            f.write(arr.tobytes())
    manifest.append("# weights.bin: flat f32 LE, order per model.weight_specs")

    with open(os.path.join(out, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} manifest entries to {out}/manifest.tsv")


if __name__ == "__main__":
    main()
