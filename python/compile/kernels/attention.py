"""L1: the Fig. 3 fused attention-like kernel in Pallas.

O = MatMul(Exp(MatMul(Q, K)), V), with the Exp applied *directly to the
blocked tile* while it sits in VMEM — the "pass-through layout" the
paper's MetaPackOperation + FoldNopPack rules discover (§3.1.2, Eq. 1):
no unpack between the first matmul and the exp, no pack before the second
matmul. The grid walks M blocks; K and V stream through whole.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(q_ref, k_ref, v_ref, o_ref):
    # Step 1: blocked matmul tile (stays in VMEM).
    s = jnp.dot(
        q_ref[...].astype(jnp.float32),
        k_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    # Step 2: Exp on the blocked tile — the 16x16 block is treated as one
    # contiguous vector of 256 lanes (no layout restore).
    e = jnp.exp(s)
    # Step 3: second blocked matmul straight from the blocked layout.
    o_ref[...] = jnp.dot(
        e, v_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def attention_exp(q, k, v, *, bm=16):
    """Fused O = exp(Q @ K) @ V over an M-blocked grid."""
    m, d = q.shape
    d2, n = k.shape
    n2, dv = v.shape
    assert d == d2 and n == n2, "shape mismatch"
    assert m % bm == 0, f"bm {bm} must divide M {m}"
    grid = (m // bm,)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
            pl.BlockSpec((n, dv), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, dv), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, dv), q.dtype),
        interpret=True,
    )(q, k, v)
