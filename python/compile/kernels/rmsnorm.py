"""L1: RMSNorm as a row-blocked Pallas kernel."""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x / jnp.sqrt(ms + eps) * w_ref[...]).astype(o_ref.dtype)


def rmsnorm(x, w, *, eps=1e-6, block_rows=8):
    """RMS-normalize the last axis of a [rows, h] tensor."""
    rows, h = x.shape
    assert w.shape == (h,)
    if rows % block_rows != 0:
        block_rows = 1
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, h), x.dtype),
        interpret=True,
    )(x, w)
