"""L1: blocked matmul as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper packs for
AVX2/AMX on x86; on TPU-style hardware the same insight becomes VMEM
tiling for the MXU systolic array. The BlockSpec grid expresses the
HBM↔VMEM staging schedule (what the paper does with cache-level tiling),
and the (bm, bk, bn) block shapes are the MXU-aligned pack sizes.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO ops and runs (and is
validated) on CPU; real-TPU performance is estimated analytically in
DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, nsteps):
    """Grid (M/bm, N/bn, K/bk); K is innermost so the output block stays
    resident in VMEM across the accumulation (double-buffered A/B tiles).
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        y_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)

    del nsteps  # shape bookkeeping only


def matmul(x, y, *, bm=16, bk=16, bn=16):
    """C = X @ Y over an (M/bm, N/bn, K/bk) Pallas grid.

    Block sizes default to the 16x16 tensor-unit tiles the paper's
    MetaPackOperation generates; all dims must divide evenly.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul k mismatch {k} vs {k2}"
    # Degrade block sizes gracefully for thin shapes (e.g. the M=1 decode
    # GEMV): fall back to the GCD so the grid still tiles exactly.
    import math

    bm = bm if m % bm == 0 else math.gcd(m, bm)
    bk = bk if k % bk == 0 else math.gcd(k, bk)
    bn = bn if n % bn == 0 else math.gcd(n, bn)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (
        f"block sizes ({bm},{bk},{bn}) must divide ({m},{k},{n})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def vmem_footprint_bytes(bm, bk, bn, dtype_bytes=4):
    """Analytical VMEM footprint of one grid step (A, B, C tiles, double-
    buffered inputs) — the §Perf L1 metric."""
    return dtype_bytes * (2 * (bm * bk + bk * bn) + bm * bn)


def mxu_utilization(bm, bk, bn, mxu=(128, 128)):
    """Estimated MXU utilization of the block shape: fraction of the
    systolic array's lanes a (bm, bk)x(bk, bn) issue keeps busy."""
    return min(1.0, bm / mxu[0]) * min(1.0, bn / mxu[1])
