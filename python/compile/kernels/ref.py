"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package has a reference implementation here;
pytest asserts allclose between the two across shapes and dtypes. These
are also the semantics the Rust NTT kernels implement, so the oracle
chain is: Pallas kernel == jnp reference == (via PJRT artifacts) Rust NTT.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """C = X @ Y with f32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32)).astype(x.dtype)


def attention_exp_ref(q, k, v):
    """The Fig. 3 subgraph: O = MatMul(Exp(MatMul(Q, K)), V).

    Deliberately *not* softmax — the paper's Auto Vectorize example uses a
    bare Exp between the two matmuls (the pass-through blocked layout).
    """
    s = jnp.matmul(q.astype(jnp.float32), k.astype(jnp.float32))
    return jnp.matmul(jnp.exp(s), v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, w, eps=1e-6):
    """RMS normalization over the last axis."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf / jnp.sqrt(ms + eps) * w).astype(x.dtype)


def softmax_ref(x, axis=-1):
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def rope_ref(x, pos, theta):
    """Rotary embedding, half-split convention (matches rust
    ``ntt::rope_inplace``): pairs ``(i, i + d/2)``, ``freq =
    theta**(-2i/d)``."""
    d = x.shape[-1]
    half = d // 2
    i = jnp.arange(half, dtype=jnp.float32)
    freq = 1.0 / (theta ** (2.0 * i / d))
    angle = pos * freq
    sin, cos = jnp.sin(angle), jnp.cos(angle)
    a, b = x[..., :half], x[..., half:]
    return jnp.concatenate([a * cos - b * sin, a * sin + b * cos], axis=-1)
