"""L2: the Qwen3-tiny decode step in JAX, calling the L1 Pallas kernels.

The decode step mirrors the Rust NTT engine semantics exactly (RMSNorm →
GQA attention with half-split RoPE and per-position KV cache → SwiGLU
MLP → final norm → LM head) so the two stacks can be cross-validated
numerically through the PJRT artifacts.

Weights are generated here deterministically (`init_params`) and saved by
aot.py as `artifacts/weights.bin`; the Rust side loads the same file, so
both stacks compute over identical parameters.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import attention_exp  # noqa: F401  (exported artifact)
from .kernels.matmul import matmul
from .kernels.rmsnorm import rmsnorm
from .kernels import ref


@dataclasses.dataclass(frozen=True)
class TinyConfig:
    """Must match rust `Qwen3Config::tiny()`."""

    hidden: int = 256
    layers: int = 4
    heads: int = 4
    kv_heads: int = 2
    head_dim: int = 64
    intermediate: int = 768
    vocab: int = 4096
    rope_theta: float = 1.0e4
    rms_eps: float = 1e-6
    max_seq: int = 16


# Weight tensor order in weights.bin (row-major f32, little endian).
def weight_specs(cfg: TinyConfig):
    specs = [("embedding", (cfg.vocab, cfg.hidden))]
    qd = cfg.heads * cfg.head_dim
    kvd = cfg.kv_heads * cfg.head_dim
    for l in range(cfg.layers):
        specs += [
            (f"l{l}.attn_norm", (cfg.hidden,)),
            (f"l{l}.wq", (cfg.hidden, qd)),
            (f"l{l}.wk", (cfg.hidden, kvd)),
            (f"l{l}.wv", (cfg.hidden, kvd)),
            (f"l{l}.wo", (qd, cfg.hidden)),
            (f"l{l}.mlp_norm", (cfg.hidden,)),
            (f"l{l}.w_gate", (cfg.hidden, cfg.intermediate)),
            (f"l{l}.w_up", (cfg.hidden, cfg.intermediate)),
            (f"l{l}.w_down", (cfg.intermediate, cfg.hidden)),
        ]
    specs += [("final_norm", (cfg.hidden,)), ("lm_head", (cfg.hidden, cfg.vocab))]
    return specs


def init_params(cfg: TinyConfig, seed: int = 0):
    """Deterministic random weights (numpy RNG; norms initialized to 1)."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in weight_specs(cfg):
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            scale = 0.02 if not name.endswith(("wo", "w_down")) else 0.02 / np.sqrt(
                2.0 * cfg.layers
            )
            params[name] = jnp.asarray(
                rng.standard_normal(shape, dtype=np.float32) * scale
            )
    return params


def rope(x, pos, theta):
    return ref.rope_ref(x, pos, theta)


def decode_step(params, cfg: TinyConfig, x_emb, kcache, vcache, pos):
    """One decode step.

    Args:
      x_emb: [1, hidden] current token embedding.
      kcache/vcache: [layers, max_seq, kv_heads*head_dim] (already roped
        K; rows >= pos are ignored via masking).
      pos: scalar int32 position of the current token.

    Returns:
      (logits [1, vocab], k_new [layers, kvd], v_new [layers, kvd])
    """
    h = cfg.hidden
    hd = cfg.head_dim
    group = cfg.heads // cfg.kv_heads
    x = x_emb.reshape(1, h)
    k_news, v_news = [], []
    posf = pos.astype(jnp.float32)
    for l in range(cfg.layers):
        xn = rmsnorm(x, params[f"l{l}.attn_norm"], eps=cfg.rms_eps)
        q = matmul(xn, params[f"l{l}.wq"])  # [1, qd]
        k = matmul(xn, params[f"l{l}.wk"])  # [1, kvd]
        v = matmul(xn, params[f"l{l}.wv"])  # [1, kvd]
        # RoPE per head (half-split convention).
        q = q.reshape(cfg.heads, hd)
        q = jax.vmap(lambda row: rope(row, posf, cfg.rope_theta))(q)
        k = k.reshape(cfg.kv_heads, hd)
        k = jax.vmap(lambda row: rope(row, posf, cfg.rope_theta))(k)
        k_news.append(k.reshape(-1))
        v_news.append(v.reshape(-1))
        # Attention over cache rows [0, pos) plus the current k/v.
        kc = kcache[l].reshape(cfg.max_seq, cfg.kv_heads, hd)
        vc = vcache[l].reshape(cfg.max_seq, cfg.kv_heads, hd)
        v = v.reshape(cfg.kv_heads, hd)
        outs = []
        mask_hist = (jnp.arange(cfg.max_seq) < pos).astype(jnp.float32)
        for head in range(cfg.heads):
            kvh = head // group
            qrow = q[head]  # [hd]
            hist = jnp.einsum("sh,h->s", kc[:, kvh, :], qrow) / jnp.sqrt(float(hd))
            cur = jnp.dot(k[kvh], qrow) / jnp.sqrt(float(hd))
            scores = jnp.concatenate([hist, cur[None]])
            neg = jnp.float32(-1e30)
            mask = jnp.concatenate([mask_hist, jnp.ones((1,), jnp.float32)])
            scores = jnp.where(mask > 0, scores, neg)
            probs = ref.softmax_ref(scores)
            ctx = jnp.einsum("s,sh->h", probs[: cfg.max_seq], vc[:, kvh, :]) + probs[
                cfg.max_seq
            ] * v[kvh]
            outs.append(ctx)
        ctx = jnp.concatenate(outs).reshape(1, cfg.heads * hd)
        attn_out = matmul(ctx, params[f"l{l}.wo"])
        x = x + attn_out
        # SwiGLU MLP.
        xn2 = rmsnorm(x, params[f"l{l}.mlp_norm"], eps=cfg.rms_eps)
        gate = matmul(xn2, params[f"l{l}.w_gate"])
        up = matmul(xn2, params[f"l{l}.w_up"])
        gate = gate * jax.nn.sigmoid(gate)
        x = x + matmul(gate * up, params[f"l{l}.w_down"])
    xn = rmsnorm(x, params["final_norm"], eps=cfg.rms_eps)
    logits = matmul(xn, params["lm_head"])
    return logits, jnp.stack(k_news), jnp.stack(v_news)


def decode_step_fn(cfg: TinyConfig, seed: int = 0):
    """Closure with baked weights, ready for jit/lowering."""
    params = init_params(cfg, seed)

    @functools.wraps(decode_step)
    def fn(x_emb, kcache, vcache, pos):
        return decode_step(params, cfg, x_emb, kcache, vcache, pos)

    return fn, params


def decode_step_args_fn(cfg: TinyConfig):
    """Variant taking the weights as *positional arguments* (in
    `weight_specs` order, embedding excluded) ahead of the activations.

    Why: the AOT interchange is HLO **text**, and `as_hlo_text()` elides
    large constant literals (`constant({...})`), so baked weights do not
    survive the text round-trip. Passing them as arguments keeps the
    artifact small and lets the Rust side feed the same `weights.bin`
    tensors it uses for the NTT engine.
    """
    specs = [s for s in weight_specs(cfg) if s[0] != "embedding"]

    def fn(*args):
        ws = args[: len(specs)]
        x_emb, kcache, vcache, pos = args[len(specs):]
        params = {name: w for (name, _), w in zip(specs, ws)}
        return decode_step(params, cfg, x_emb, kcache, vcache, pos)

    return fn, specs


def reference_decode(params, cfg: TinyConfig, tokens, n_steps):
    """Pure-python greedy decode used by pytest to sanity-check the jitted
    decode_step against an un-jitted run."""
    kcache = jnp.zeros((cfg.layers, cfg.max_seq, cfg.kv_heads * cfg.head_dim))
    vcache = jnp.zeros_like(kcache)
    pos = 0
    logits = None
    for t in tokens:
        x = params["embedding"][t][None, :]
        logits, knew, vnew = decode_step(
            params, cfg, x, kcache, vcache, jnp.int32(pos)
        )
        kcache = kcache.at[:, pos, :].set(knew)
        vcache = vcache.at[:, pos, :].set(vnew)
        pos += 1
    out = []
    for _ in range(n_steps):
        t = int(jnp.argmax(logits))
        out.append(t)
        x = params["embedding"][t][None, :]
        logits, knew, vnew = decode_step(
            params, cfg, x, kcache, vcache, jnp.int32(pos)
        )
        kcache = kcache.at[:, pos, :].set(knew)
        vcache = vcache.at[:, pos, :].set(vnew)
        pos += 1
    return out
